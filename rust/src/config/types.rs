//! Typed experiment configuration, with file loading and `key=value`
//! overrides (so CLI flags always win over the config file).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::parser::{parse_toml, parse_value, TomlDoc, TomlValue};

/// Accept string-like scenario axis values: `traffic = 4` and
/// `traffic = "4"` must both work.
fn spec_string(value: &TomlValue) -> Result<String> {
    Ok(match value {
        TomlValue::Str(s) => s.clone(),
        TomlValue::Int(i) => i.to_string(),
        TomlValue::Float(f) => f.to_string(),
        other => bail!("expected a spec string, got {other:?}"),
    })
}

/// Protocol parameters (paper Sec. 2). Times are normalized units.
#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    /// Block payload size n_c (samples). 0 = "optimize via the bound".
    pub n_c: usize,
    /// Per-packet overhead n_o.
    pub n_o: f64,
    /// Time per SGD update τ_p.
    pub tau_p: f64,
    /// Deadline T as a multiple of N (paper: 1.5). Used unless t_abs set.
    pub t_factor: f64,
    /// Absolute deadline (overrides t_factor when > 0).
    pub t_abs: f64,
}

impl Default for ProtocolConfig {
    fn default() -> Self {
        ProtocolConfig {
            n_c: 0,
            n_o: 10.0,
            tau_p: 1.0,
            t_factor: 1.5,
            t_abs: 0.0,
        }
    }
}

impl ProtocolConfig {
    /// The deadline T for a dataset of `n` samples.
    pub fn deadline(&self, n: usize) -> f64 {
        if self.t_abs > 0.0 {
            self.t_abs
        } else {
            self.t_factor * n as f64
        }
    }
}

/// Training parameters (paper Sec. 5).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Learning rate α.
    pub alpha: f64,
    /// Ridge regularization λ.
    pub lambda: f64,
    /// Std of the Gaussian parameter init (paper: 1.0).
    pub init_std: f64,
    /// Master seed for the run.
    pub seed: u64,
    /// Record the loss every `loss_stride` normalized time units
    /// (0 = record at block boundaries only).
    pub loss_stride: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            alpha: 1e-4,
            lambda: 0.05,
            init_std: 1.0,
            seed: 1,
            loss_stride: 0.0,
        }
    }
}

/// Dataset parameters (paper Sec. 5; defaults reproduce its setup).
#[derive(Clone, Debug)]
pub struct DataConfig {
    /// Raw dataset size before the train split.
    pub n_raw: usize,
    /// Feature dimension.
    pub d: usize,
    /// Train fraction (paper: 0.9 -> N = 18 576).
    pub train_frac: f64,
    /// Target Hessian max eigenvalue (paper L).
    pub hess_max: f64,
    /// Target Hessian min eigenvalue (paper c).
    pub hess_min: f64,
    /// Label noise std.
    pub noise_std: f64,
    /// Dataset seed (independent of the run seed).
    pub seed: u64,
    /// Optional CSV path: when set, load instead of synthesizing.
    pub csv_path: String,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            n_raw: 20640,
            d: 8,
            train_frac: 0.9,
            hess_max: 1.908,
            hess_min: 0.061,
            noise_std: 0.5,
            seed: 1906_04488,
            csv_path: String::new(),
        }
    }
}

/// Sweep parameters for figure/bench producers.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Overheads to sweep (Fig. 3 curves).
    pub n_os: Vec<f64>,
    /// Block sizes to sweep (empty = log grid).
    pub n_cs: Vec<usize>,
    /// Monte-Carlo repetitions per point.
    pub seeds: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            n_os: vec![1.0, 10.0, 100.0, 1000.0],
            n_cs: Vec::new(),
            seeds: 10,
            threads: 0,
        }
    }
}

/// Scenario selection for the generic sweeps (`edgepipe scenario`): the
/// compact axis strings parsed by `sweep::scenario`.
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    /// Channel spec: `ideal` | `erasure:<p>` | `rate:<r>[:<p>]` |
    /// `fading:<p_gb>:<p_bg>:<p_bad>[:<p_good>[:<r_bad>[:<r_good>]]]`.
    pub channel: String,
    /// Policy spec: `fixed[:n_c]` | `warmup:<start>:<growth>[:<cap>]` |
    /// `deadline:<frac>` | `sequential[:n_c]` | `allfirst` |
    /// `control[:est=<ge|ema>][:replan=<k>]` (closed-loop
    /// channel-adaptive re-planning).
    pub policy: String,
    /// Traffic spec: `<k>` round-robin devices | `online:<rate>` |
    /// `devices:<k>[:sched=<rr|greedy|pfair>][:skew=<f>][:ch=<list>]`.
    pub traffic: String,
    /// Workload spec: `ridge` | `logistic`.
    pub workload: String,
    /// Edge store capacity (0 = unbounded).
    pub store: usize,
    /// Per-device channel list for heterogeneous sweeps (comma-separated
    /// `ChannelSpec`s; empty = lanes inherit the channel axis). Upgrades
    /// plain `<k>` traffic specs to the heterogeneous uplink when set.
    pub device_channels: String,
    /// Device scheduler for heterogeneous sweeps: `rr` | `greedy` |
    /// `pfair`.
    pub device_sched: String,
    /// Label skew of the device shards in [0, 1].
    pub device_skew: f64,
    /// Fault-injection spec applied to the channel axis (see
    /// `channel::fault::FaultSpec`): `off` (or empty) disables, else
    /// `+`-joined clauses like `outage:<start>:<dur>[:<period>]`,
    /// `ackloss:<p>`, `drop:<device>:<t>`,
    /// `preempt:<start>:<dur>[:<period>]`,
    /// `retry:<timeout>[:<budget>[:<evict>]]`.
    pub fault: String,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            channel: "ideal".to_string(),
            policy: "fixed".to_string(),
            traffic: "1".to_string(),
            workload: "ridge".to_string(),
            store: 0,
            device_channels: String::new(),
            device_sched: "rr".to_string(),
            device_skew: 0.0,
            fault: String::new(),
        }
    }
}

/// Every key [`ExperimentConfig::from_doc`] accepts — the unknown-key
/// typo guard lists these so a near-miss is self-correcting.
pub const VALID_KEYS: &[&str] = &[
    "protocol.n_c",
    "protocol.n_o",
    "protocol.tau_p",
    "protocol.t_factor",
    "protocol.t_abs",
    "train.alpha",
    "train.lambda",
    "train.init_std",
    "train.seed",
    "train.loss_stride",
    "data.n_raw",
    "data.d",
    "data.train_frac",
    "data.hess_max",
    "data.hess_min",
    "data.noise_std",
    "data.seed",
    "data.csv_path",
    "sweep.n_os",
    "sweep.n_cs",
    "sweep.seeds",
    "sweep.threads",
    "scenario.channel",
    "scenario.policy",
    "scenario.traffic",
    "scenario.workload",
    "scenario.store",
    "scenario.device_channels",
    "scenario.device_sched",
    "scenario.device_skew",
    "scenario.fault",
];

/// The full experiment configuration.
#[derive(Clone, Debug, Default)]
pub struct ExperimentConfig {
    pub protocol: ProtocolConfig,
    pub train: TrainConfig,
    pub data: DataConfig,
    pub sweep: SweepConfig,
    pub scenario: ScenarioConfig,
}

impl ExperimentConfig {
    /// Load from a TOML file, then apply `key=value` overrides.
    pub fn load(
        path: Option<&Path>,
        overrides: &[(String, String)],
    ) -> Result<ExperimentConfig> {
        let mut doc = match path {
            Some(p) => {
                let text = std::fs::read_to_string(p)
                    .with_context(|| format!("reading {}", p.display()))?;
                parse_toml(&text)?
            }
            None => TomlDoc::new(),
        };
        for (k, v) in overrides {
            doc.insert(k.clone(), parse_value(v)?);
        }
        Self::from_doc(&doc)
    }

    /// Build from a parsed document; unknown keys are rejected (typo guard).
    pub fn from_doc(doc: &TomlDoc) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        for (key, value) in doc {
            match key.as_str() {
                "protocol.n_c" => cfg.protocol.n_c = value.as_usize()?,
                "protocol.n_o" => cfg.protocol.n_o = value.as_f64()?,
                "protocol.tau_p" => cfg.protocol.tau_p = value.as_f64()?,
                "protocol.t_factor" => {
                    cfg.protocol.t_factor = value.as_f64()?
                }
                "protocol.t_abs" => cfg.protocol.t_abs = value.as_f64()?,
                "train.alpha" => cfg.train.alpha = value.as_f64()?,
                "train.lambda" => cfg.train.lambda = value.as_f64()?,
                "train.init_std" => cfg.train.init_std = value.as_f64()?,
                "train.seed" => cfg.train.seed = value.as_u64()?,
                "train.loss_stride" => {
                    cfg.train.loss_stride = value.as_f64()?
                }
                "data.n_raw" => cfg.data.n_raw = value.as_usize()?,
                "data.d" => cfg.data.d = value.as_usize()?,
                "data.train_frac" => cfg.data.train_frac = value.as_f64()?,
                "data.hess_max" => cfg.data.hess_max = value.as_f64()?,
                "data.hess_min" => cfg.data.hess_min = value.as_f64()?,
                "data.noise_std" => cfg.data.noise_std = value.as_f64()?,
                "data.seed" => cfg.data.seed = value.as_u64()?,
                "data.csv_path" => {
                    cfg.data.csv_path = value.as_str()?.to_string()
                }
                "sweep.n_os" => cfg.sweep.n_os = value.as_f64_arr()?,
                "sweep.n_cs" => cfg.sweep.n_cs = value.as_usize_arr()?,
                "sweep.seeds" => cfg.sweep.seeds = value.as_usize()?,
                "sweep.threads" => cfg.sweep.threads = value.as_usize()?,
                "scenario.channel" => {
                    cfg.scenario.channel = spec_string(value)?
                }
                "scenario.policy" => {
                    cfg.scenario.policy = spec_string(value)?
                }
                "scenario.traffic" => {
                    cfg.scenario.traffic = spec_string(value)?
                }
                "scenario.workload" => {
                    cfg.scenario.workload = spec_string(value)?
                }
                "scenario.store" => {
                    cfg.scenario.store = value.as_usize()?
                }
                "scenario.device_channels" => {
                    cfg.scenario.device_channels = spec_string(value)?
                }
                "scenario.device_sched" => {
                    cfg.scenario.device_sched = spec_string(value)?
                }
                "scenario.device_skew" => {
                    cfg.scenario.device_skew = value.as_f64()?
                }
                "scenario.fault" => {
                    cfg.scenario.fault = spec_string(value)?
                }
                other => {
                    // point typos at the nearest section's key list
                    let section =
                        other.split('.').next().unwrap_or(other);
                    let near: Vec<&str> = VALID_KEYS
                        .iter()
                        .copied()
                        .filter(|k| {
                            k.starts_with(section) && k[section.len()..]
                                .starts_with('.')
                        })
                        .collect();
                    let hint = if near.is_empty() {
                        VALID_KEYS.join(", ")
                    } else {
                        near.join(", ")
                    };
                    bail!(
                        "unknown config key '{other}' (valid keys: {hint})"
                    )
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check the configuration.
    pub fn validate(&self) -> Result<()> {
        if self.protocol.tau_p <= 0.0 {
            bail!("protocol.tau_p must be positive");
        }
        if self.protocol.n_o < 0.0 {
            bail!("protocol.n_o must be non-negative");
        }
        if self.protocol.t_factor <= 0.0 && self.protocol.t_abs <= 0.0 {
            bail!("need a positive deadline (t_factor or t_abs)");
        }
        if self.train.alpha <= 0.0 {
            bail!("train.alpha must be positive");
        }
        if !(0.0..=1.0).contains(&self.data.train_frac) {
            bail!("data.train_frac must be in [0, 1]");
        }
        if self.data.n_raw == 0 || self.data.d == 0 {
            bail!("dataset must be non-empty");
        }
        if self.data.hess_min <= 0.0 || self.data.hess_max <= self.data.hess_min
        {
            bail!("need 0 < hess_min < hess_max");
        }
        if !(0.0..=1.0).contains(&self.scenario.device_skew) {
            bail!("scenario.device_skew must be in [0, 1]");
        }
        if self.sweep.seeds == 0 {
            bail!(
                "sweep.seeds must be >= 1 (a 0-seed Monte-Carlo estimate \
                 is undefined)"
            );
        }
        crate::channel::FaultSpec::parse(&self.scenario.fault)
            .context("bad scenario.fault")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_reproduce_paper_setup() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.data.n_raw, 20640);
        assert_eq!(cfg.data.d, 8);
        assert_eq!(cfg.train.alpha, 1e-4);
        assert_eq!(cfg.train.lambda, 0.05);
        let n = (cfg.data.n_raw as f64 * cfg.data.train_frac) as usize;
        assert_eq!(n, 18576);
        assert_eq!(cfg.protocol.deadline(n), 1.5 * 18576.0);
    }

    #[test]
    fn loads_doc_with_overrides() {
        let doc = parse_toml(
            "[protocol]\nn_c = 437\nn_o = 100.0\n[train]\nseed = 9\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.protocol.n_c, 437);
        assert_eq!(cfg.protocol.n_o, 100.0);
        assert_eq!(cfg.train.seed, 9);
        // untouched defaults survive
        assert_eq!(cfg.train.lambda, 0.05);
    }

    #[test]
    fn unknown_key_is_rejected() {
        let doc = parse_toml("[protocol]\nn_x = 1\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn unknown_key_error_lists_valid_keys() {
        let doc = parse_toml("[scenario]\nfualt = \"off\"\n").unwrap();
        let err =
            ExperimentConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("unknown config key 'scenario.fualt'"), "{err}");
        // the hint is scoped to the typo'd section and names the fix
        assert!(err.contains("scenario.fault"), "{err}");
        assert!(err.contains("scenario.channel"), "{err}");
        assert!(!err.contains("train.alpha"), "{err}");
        // a key with no recognizable section lists everything
        let doc = parse_toml("bogus = 1\n").unwrap();
        let err =
            ExperimentConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("train.alpha"), "{err}");
    }

    #[test]
    fn fault_key_loads_and_validates() {
        let cfg = ExperimentConfig::load(
            None,
            &[(
                "scenario.fault".into(),
                "outage:100:25+retry:4:2:2".into(),
            )],
        )
        .unwrap();
        assert_eq!(cfg.scenario.fault, "outage:100:25+retry:4:2:2");
        assert_eq!(ExperimentConfig::default().scenario.fault, "");
        // a malformed spec is rejected at load time, not run time
        assert!(ExperimentConfig::load(
            None,
            &[("scenario.fault".into(), "meteor:1".into())],
        )
        .is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let doc = parse_toml("[train]\nalpha = -1.0\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        let doc = parse_toml("[protocol]\ntau_p = 0.0\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn zero_seed_sweeps_are_rejected_at_the_boundary() {
        // seeds = 0 would produce an undefined (NaN) MC estimate; both
        // the TOML and the --set override routes must refuse it early
        let doc = parse_toml("[sweep]\nseeds = 0\n").unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("sweep.seeds"), "{err}");
        assert!(ExperimentConfig::load(
            None,
            &[("sweep.seeds".into(), "0".into())],
        )
        .is_err());
        assert!(ExperimentConfig::load(
            None,
            &[("sweep.seeds".into(), "1".into())],
        )
        .is_ok());
    }

    #[test]
    fn override_wins() {
        let cfg = ExperimentConfig::load(
            None,
            &[("protocol.n_o".into(), "123.5".into())],
        )
        .unwrap();
        assert_eq!(cfg.protocol.n_o, 123.5);
    }

    #[test]
    fn scenario_keys_load() {
        let cfg = ExperimentConfig::load(
            None,
            &[
                ("scenario.channel".into(), "fading:0.05:0.25:0.6".into()),
                ("scenario.policy".into(), "warmup:8:2.0".into()),
                ("scenario.traffic".into(), "4".into()),
                ("scenario.workload".into(), "logistic".into()),
                ("scenario.store".into(), "500".into()),
            ],
        )
        .unwrap();
        assert_eq!(cfg.scenario.channel, "fading:0.05:0.25:0.6");
        assert_eq!(cfg.scenario.policy, "warmup:8:2.0");
        assert_eq!(cfg.scenario.traffic, "4");
        assert_eq!(cfg.scenario.workload, "logistic");
        assert_eq!(cfg.scenario.store, 500);
        // defaults
        let d = ExperimentConfig::default();
        assert_eq!(d.scenario.channel, "ideal");
        assert_eq!(d.scenario.traffic, "1");
        assert_eq!(d.scenario.workload, "ridge");
        assert_eq!(d.scenario.device_channels, "");
        assert_eq!(d.scenario.device_sched, "rr");
        assert_eq!(d.scenario.device_skew, 0.0);
    }

    #[test]
    fn device_keys_load_and_validate() {
        let cfg = ExperimentConfig::load(
            None,
            &[
                (
                    "scenario.traffic".into(),
                    "devices:4:sched=greedy".into(),
                ),
                (
                    "scenario.device_channels".into(),
                    "ideal,erasure:0.2,fading:0.05:0.25:0.6,rate:0.5".into(),
                ),
                ("scenario.device_sched".into(), "pfair".into()),
                ("scenario.device_skew".into(), "0.7".into()),
            ],
        )
        .unwrap();
        assert_eq!(cfg.scenario.traffic, "devices:4:sched=greedy");
        assert_eq!(
            cfg.scenario.device_channels,
            "ideal,erasure:0.2,fading:0.05:0.25:0.6,rate:0.5"
        );
        assert_eq!(cfg.scenario.device_sched, "pfair");
        assert_eq!(cfg.scenario.device_skew, 0.7);
        // skew outside [0, 1] is rejected
        assert!(ExperimentConfig::load(
            None,
            &[("scenario.device_skew".into(), "1.2".into())],
        )
        .is_err());
    }

    #[test]
    fn t_abs_overrides_factor() {
        let cfg = ExperimentConfig::load(
            None,
            &[("protocol.t_abs".into(), "5000".into())],
        )
        .unwrap();
        assert_eq!(cfg.protocol.deadline(18576), 5000.0);
    }
}
