//! In-repo micro/macro benchmark harness (the offline image has no
//! criterion). Used by every target in `benches/` via
//! `[[bench]] harness = false`.
//!
//! Features: warmup, timed iterations with per-iteration samples,
//! mean/p50/p99, throughput reporting, `--filter substring` selection and
//! `EDGEPIPE_BENCH_FAST=1` for CI-speed runs.
//!
//! [`sweep`] holds the tracked sweep benchmark (baseline-vs-optimized
//! engine shapes, `BENCH_sweep.json`), shared by `edgepipe bench` and
//! `cargo bench --bench bench_sweep`.

pub mod sweep;

use std::time::{Duration, Instant};

use crate::util::stats::Summary;
use crate::util::timefmt::{fmt_duration, fmt_rate};

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Recorded iterations.
    pub iters: usize,
    /// Substring filter from `--filter` (empty = run all).
    pub filter: String,
}

impl BenchConfig {
    /// Build from env + argv (`--filter X`, `EDGEPIPE_BENCH_FAST`;
    /// `"0"`/`""` count as unset).
    pub fn from_env() -> BenchConfig {
        let fast = sweep::env_flag("EDGEPIPE_BENCH_FAST");
        let mut filter = String::new();
        let args: Vec<String> = std::env::args().collect();
        for i in 0..args.len() {
            if args[i] == "--filter" && i + 1 < args.len() {
                filter = args[i + 1].clone();
            }
        }
        BenchConfig {
            warmup: if fast { 1 } else { 3 },
            iters: if fast { 3 } else { 10 },
            filter,
        }
    }
}

/// One benchmark's outcome.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// Work units per iteration (for throughput; 0 = skip).
    pub units_per_iter: f64,
}

impl BenchResult {
    /// One formatted report line.
    pub fn report(&self) -> String {
        let s = &self.summary;
        let mut line = format!(
            "{:<44} mean {:>10}  p50 {:>10}  p99 {:>10}",
            self.name,
            fmt_duration(Duration::from_secs_f64(s.mean)),
            fmt_duration(Duration::from_secs_f64(s.p50)),
            fmt_duration(Duration::from_secs_f64(s.p99)),
        );
        if self.units_per_iter > 0.0 && s.mean > 0.0 {
            line.push_str(&format!(
                "  [{}]",
                fmt_rate(self.units_per_iter / s.mean)
            ));
        }
        line
    }
}

/// The harness: collects results, prints a report.
pub struct Bench {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new() -> Bench {
        let cfg = BenchConfig::from_env();
        Bench { cfg, results: Vec::new() }
    }

    /// Should this benchmark run under the current filter?
    pub fn enabled(&self, name: &str) -> bool {
        self.cfg.filter.is_empty() || name.contains(&self.cfg.filter)
    }

    /// Time `f` (warmup + recorded iterations). `units_per_iter` drives
    /// the throughput column (e.g. SGD updates per iteration).
    pub fn run<F: FnMut()>(
        &mut self,
        name: &str,
        units_per_iter: f64,
        mut f: F,
    ) {
        if !self.enabled(name) {
            return;
        }
        for _ in 0..self.cfg.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.cfg.iters);
        for _ in 0..self.cfg.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
            units_per_iter,
        };
        println!("{}", result.report());
        self.results.push(result);
    }

    /// Run once (macro-benchmarks that print their own tables).
    pub fn run_once<F: FnOnce()>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        println!("=== {name} ===");
        let t0 = Instant::now();
        f();
        println!("=== {name} done in {} ===", fmt_duration(t0.elapsed()));
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_records_samples() {
        let mut b = Bench {
            cfg: BenchConfig { warmup: 1, iters: 4, filter: String::new() },
            results: Vec::new(),
        };
        let mut count = 0;
        b.run("noop", 100.0, || count += 1);
        assert_eq!(count, 5); // 1 warmup + 4 recorded
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].summary.n, 4);
        assert!(b.results()[0].report().contains("noop"));
    }

    #[test]
    fn filter_skips() {
        let mut b = Bench {
            cfg: BenchConfig {
                warmup: 0,
                iters: 1,
                filter: "match".into(),
            },
            results: Vec::new(),
        };
        let mut ran = false;
        b.run("no", 0.0, || ran = true);
        assert!(!ran);
        b.run("does match", 0.0, || ran = true);
        assert!(ran);
    }
}
