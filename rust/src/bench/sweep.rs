//! The tracked sweep benchmark: Monte-Carlo `mc_final_loss`-style
//! throughput, measured three ways in one process —
//!
//! * **baseline** — the pre-workspace engine shape: one pool spawn per
//!   grid point, a fresh allocation set per run (`ScenarioRunner::run`);
//! * **optimized** — the scalar engine: ONE flat `(n_c, seed)` fan-out,
//!   per-worker [`RunWorkspace`] reuse (`ScenarioRunner::run_with`);
//! * **batched** — the batched-seed engine (`sweep/batch.rs`) at each
//!   supported lane width L ∈ {4, 8, 16}: the identical job list chunked
//!   into seed-groups, traced once and replayed through SoA kernels.
//!
//! A fourth phase measures the sharded DES at fleet scale: for each
//! device count k in [`SweepBenchConfig::devices`] (up to 10 240 in the
//! full preset), the identical k-device scenario runs through the
//! inline single-shard event loop and again over [`SCALING_SHARDS`]
//! shard workers, asserted bit-identical, producing the
//! `device_scaling` rows.
//!
//! All phases compute bit-identical losses (asserted), so the ratios are
//! pure engine overhead. `edgepipe bench --json BENCH_sweep.json` and
//! `cargo bench --bench bench_sweep` both emit the same
//! `BENCH_sweep.json` (schema 3) so future PRs can regress against a
//! recorded baseline: compare `runs_per_sec`, the per-lane `lanes`
//! rows and the `device_scaling` rows (and `allocs_per_run`, when the
//! counting allocator is installed) across commits.
//! `EDGEPIPE_BENCH_MIN_SPEEDUP` turns the largest-lane batched speedup
//! into a hard gate (see `rust/benches/bench_sweep.rs`).

use std::time::Instant;

use crate::channel::{IdealChannel, MultiLaneChannel};
use crate::coordinator::des::DesConfig;
use crate::coordinator::executor::NativeExecutor;
use crate::coordinator::scheduler::{
    run_schedule, FixedPolicy, GreedyScheduler, OverlapMode, RunWorkspace,
};
use crate::coordinator::shard::ShardedSource;
use crate::data::shard::shard_round_robin;
use crate::data::split::train_split;
use crate::data::synth::{synth_calhousing, SynthSpec};
use crate::data::Dataset;
use crate::model::RidgeModel;
use crate::linalg::batch::LANE_WIDTHS;
use crate::sweep::batch::grouped_losses;
use crate::sweep::runner::log_grid;
use crate::sweep::scenario::{ScenarioRunner, ScenarioSpec};
use crate::util::alloc::{allocations_during, allocs_per_unit};
use crate::util::json::{num, num_arr, obj, s, Value};
use crate::util::pool::{default_threads, parallel_map_with, parallel_tasks};

/// What to measure.
#[derive(Clone, Debug)]
pub struct SweepBenchConfig {
    /// Raw synthetic dataset size (pre train-split).
    pub n: usize,
    /// Block-size grid resolution (log-spaced over `[1, n_train]`).
    pub grid_points: usize,
    /// Monte-Carlo seeds per grid point.
    pub seeds: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Per-packet overhead.
    pub n_o: f64,
    /// Device counts for the sharded-DES scaling phase (one
    /// [`DeviceScalingRow`] each). Counts exceeding the train-split
    /// size are skipped — every device needs at least one sample.
    pub devices: Vec<usize>,
}

impl SweepBenchConfig {
    /// Paper-scale workload (N = 18 576 raw → 16 718 train rows).
    /// The 10 240-device point is the fleet-scale target of the
    /// sharded DES: ~1.6 samples per device, pure scheduling overhead.
    pub fn full() -> SweepBenchConfig {
        SweepBenchConfig {
            n: 18_576,
            grid_points: 8,
            seeds: 8,
            threads: 0,
            n_o: 100.0,
            devices: vec![64, 1024, 10_240],
        }
    }

    /// CI-scale workload (seconds, not minutes).
    pub fn fast() -> SweepBenchConfig {
        SweepBenchConfig {
            n: 2_000,
            grid_points: 5,
            seeds: 4,
            threads: 0,
            n_o: 20.0,
            devices: vec![32, 256],
        }
    }

    /// `fast()` when `EDGEPIPE_BENCH_FAST` is truthy (set, non-empty,
    /// not `"0"`), else `full()`.
    pub fn from_env() -> SweepBenchConfig {
        if env_flag("EDGEPIPE_BENCH_FAST") {
            SweepBenchConfig::fast()
        } else {
            SweepBenchConfig::full()
        }
    }
}

/// Is the env var set to a truthy value (`"0"` and `""` count as
/// unset)?
pub fn env_flag(name: &str) -> bool {
    matches!(std::env::var(name), Ok(v) if !v.is_empty() && v != "0")
}

/// One batched-engine measurement at a fixed lane width, over the same
/// job list as the scalar phases.
#[derive(Clone, Copy, Debug)]
pub struct LaneBenchRow {
    /// Lane width L.
    pub lanes: usize,
    pub secs: f64,
    pub runs_per_sec: f64,
    /// SGD updates/sec through the batched engine (same update total as
    /// the scalar phases).
    pub updates_per_sec: f64,
    /// `runs_per_sec / scalar optimized runs_per_sec`.
    pub speedup: f64,
    /// Mean allocations per Monte-Carlo run (each lane is one run;
    /// None without the counting allocator).
    pub allocs_per_run: Option<f64>,
}

/// One device-count point of the sharded-DES scaling phase: the same
/// `k`-device scenario run end-to-end with the inline single-shard
/// event loop and again with [`SCALING_SHARDS`] shard workers. Both
/// runs are asserted bit-identical (loss, updates, samples) before the
/// timing is trusted — sharding is an execution strategy, not a
/// semantics.
#[derive(Clone, Copy, Debug)]
pub struct DeviceScalingRow {
    /// Device count k (one dataset shard + one channel lane each).
    pub devices: usize,
    /// Shard workers in the multi-shard run.
    pub shards: usize,
    pub secs_single: f64,
    pub secs_sharded: f64,
    /// `secs_single / secs_sharded`.
    pub speedup: f64,
    /// Samples delivered per run (identical across both runs).
    pub samples: usize,
}

/// Shard-worker count the scaling phase measures against the inline
/// single-shard loop (capped to the device count by the source).
pub const SCALING_SHARDS: usize = 4;

/// One measurement of every engine shape over the identical workload.
#[derive(Clone, Debug)]
pub struct SweepBenchReport {
    pub n_train: usize,
    pub d: usize,
    pub grid: Vec<usize>,
    pub seeds: usize,
    pub threads: usize,
    /// Total Monte-Carlo runs per phase (`grid.len() · seeds`).
    pub runs: usize,
    /// SGD updates executed per phase (identical across phases).
    pub updates: u64,
    pub baseline_secs: f64,
    pub optimized_secs: f64,
    pub baseline_runs_per_sec: f64,
    pub runs_per_sec: f64,
    /// `runs_per_sec / baseline_runs_per_sec`.
    pub speedup: f64,
    /// SGD updates/sec through the optimized engine.
    pub updates_per_sec: f64,
    /// Mean allocations per run (None without the counting allocator).
    pub allocs_per_run_baseline: Option<f64>,
    pub allocs_per_run: Option<f64>,
    /// Batched-seed engine rows, one per lane width in [`LANE_WIDTHS`].
    pub lanes: Vec<LaneBenchRow>,
    /// Sharded-DES device-count scaling rows, one per measured count.
    pub device_scaling: Vec<DeviceScalingRow>,
}

impl SweepBenchReport {
    /// Human-readable summary block.
    pub fn render(&self) -> String {
        let fmt_allocs = |a: Option<f64>| match a {
            Some(v) => format!("{v:.1}"),
            None => "n/a (counting allocator not installed)".to_string(),
        };
        let mut out = format!(
            "sweep bench: N={} d={} grid={:?} seeds={} threads={} \
             ({} runs, {} updates/phase)\n\
             \x20 baseline  (pool per point, alloc per run): \
             {:>10.3}s  {:>10.1} runs/s  allocs/run {}\n\
             \x20 optimized (one fan-out, reused workspace): \
             {:>10.3}s  {:>10.1} runs/s  allocs/run {}\n\
             \x20 speedup: {:.2}x   sgd updates/s: {:.3e}\n",
            self.n_train,
            self.d,
            self.grid,
            self.seeds,
            self.threads,
            self.runs,
            self.updates,
            self.baseline_secs,
            self.baseline_runs_per_sec,
            fmt_allocs(self.allocs_per_run_baseline),
            self.optimized_secs,
            self.runs_per_sec,
            fmt_allocs(self.allocs_per_run),
            self.speedup,
            self.updates_per_sec,
        );
        for row in &self.lanes {
            out.push_str(&format!(
                "\x20 batched L={:<2} (traced seed-groups, SoA replay): \
                 {:>10.3}s  {:>10.1} runs/s  allocs/run {}  \
                 ({:.2}x vs scalar, {:.3e} upd/s)\n",
                row.lanes,
                row.secs,
                row.runs_per_sec,
                fmt_allocs(row.allocs_per_run),
                row.speedup,
                row.updates_per_sec,
            ));
        }
        for row in &self.device_scaling {
            out.push_str(&format!(
                "\x20 devices k={:<6} (sharded DES, {} shards): \
                 single {:>9.3}s  sharded {:>9.3}s  ({:.2}x, {} samples)\n",
                row.devices,
                row.shards,
                row.secs_single,
                row.secs_sharded,
                row.speedup,
                row.samples,
            ));
        }
        out
    }

    /// The batched row at the widest measured lane count (the gate
    /// target for `EDGEPIPE_BENCH_MIN_SPEEDUP`).
    pub fn widest_lane_row(&self) -> Option<&LaneBenchRow> {
        self.lanes.iter().max_by_key(|r| r.lanes)
    }

    /// The `BENCH_sweep.json` document (schema 3: adds the sharded-DES
    /// `device_scaling` rows; schema 2 added the per-lane `lanes` rows
    /// of the batched-seed engine).
    pub fn to_value(&self) -> Value {
        let opt_num = |v: Option<f64>| match v {
            Some(x) => num(x),
            None => Value::Null,
        };
        let lane_rows: Vec<Value> = self
            .lanes
            .iter()
            .map(|r| {
                obj(vec![
                    ("lanes", num(r.lanes as f64)),
                    ("secs", num(r.secs)),
                    ("runs_per_sec", num(r.runs_per_sec)),
                    ("updates_per_sec", num(r.updates_per_sec)),
                    ("speedup", num(r.speedup)),
                    ("allocs_per_run", opt_num(r.allocs_per_run)),
                ])
            })
            .collect();
        let scaling_rows: Vec<Value> = self
            .device_scaling
            .iter()
            .map(|r| {
                obj(vec![
                    ("devices", num(r.devices as f64)),
                    ("shards", num(r.shards as f64)),
                    ("secs_single", num(r.secs_single)),
                    ("secs_sharded", num(r.secs_sharded)),
                    ("speedup", num(r.speedup)),
                    ("samples", num(r.samples as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("schema", num(3.0)),
            ("lanes", Value::Arr(lane_rows)),
            ("device_scaling", Value::Arr(scaling_rows)),
            ("bench", s("sweep")),
            ("n_train", num(self.n_train as f64)),
            ("d", num(self.d as f64)),
            (
                "grid",
                num_arr(
                    &self.grid.iter().map(|&g| g as f64).collect::<Vec<_>>(),
                ),
            ),
            ("seeds", num(self.seeds as f64)),
            ("threads", num(self.threads as f64)),
            ("runs", num(self.runs as f64)),
            ("updates", num(self.updates as f64)),
            ("baseline_secs", num(self.baseline_secs)),
            ("optimized_secs", num(self.optimized_secs)),
            ("baseline_runs_per_sec", num(self.baseline_runs_per_sec)),
            ("runs_per_sec", num(self.runs_per_sec)),
            ("speedup", num(self.speedup)),
            ("updates_per_sec", num(self.updates_per_sec)),
            (
                "allocs_per_run_baseline",
                opt_num(self.allocs_per_run_baseline),
            ),
            ("allocs_per_run", opt_num(self.allocs_per_run)),
        ])
    }
}

/// The sweep-mode run configuration both phases share.
fn bench_base(n_o: f64, t_budget: f64) -> DesConfig {
    DesConfig {
        loss_every: 0,
        record_blocks: false,
        ..DesConfig::paper(1, n_o, t_budget, 7)
    }
}

fn per_seed(base: &DesConfig, n_c: usize, s: u64) -> DesConfig {
    DesConfig {
        n_c,
        seed: base.seed.wrapping_add(s),
        ..base.clone()
    }
}

/// Run the tracked sweep benchmark: identical `(n_c, seed)` workloads
/// through the baseline and optimized engine shapes, with a bitwise
/// loss-equality assertion between the two (the optimization must not
/// change results).
pub fn run_sweep_bench(cfg: &SweepBenchConfig) -> SweepBenchReport {
    let raw = synth_calhousing(&SynthSpec { n: cfg.n, ..Default::default() });
    let (train, _) = train_split(&raw, 0.9, 42);
    let threads =
        if cfg.threads == 0 { default_threads() } else { cfg.threads };
    let t_budget = 1.5 * train.n as f64;
    let base = bench_base(cfg.n_o, t_budget);
    let grid = log_grid(train.n, cfg.grid_points).expect("bench grid");
    let runner = ScenarioRunner::new(ScenarioSpec::paper(), &train);
    let jobs: Vec<(usize, u64)> = grid
        .iter()
        .flat_map(|&n_c| (0..cfg.seeds as u64).map(move |s| (n_c, s)))
        .collect();

    // warm caches and the page allocator: one seed per grid point
    parallel_map_with(&grid, threads, RunWorkspace::new, |ws, &n_c| {
        runner
            .run_with(ws, &per_seed(&base, n_c, 0))
            .expect("warmup run failed");
    });

    // baseline shape: a pool spawn per grid point, a fresh workspace
    // (full allocation set) per run — the pre-change engine
    let (baseline_losses, baseline_allocs, baseline_secs) = timed(|| {
        let mut all: Vec<f64> = Vec::with_capacity(jobs.len());
        for &n_c in &grid {
            all.extend(parallel_tasks(cfg.seeds, threads, |s| {
                runner
                    .run(&per_seed(&base, n_c, s as u64))
                    .expect("bench run failed")
                    .final_loss
            }));
        }
        all
    });

    // optimized shape: ONE flat fan-out, per-worker workspace reuse
    let (opt_results, opt_allocs, optimized_secs) = timed(|| {
        parallel_map_with(
            &jobs,
            threads,
            RunWorkspace::new,
            |ws, &(n_c, s)| {
                let stats = runner
                    .run_with(ws, &per_seed(&base, n_c, s))
                    .expect("bench run failed");
                (stats.final_loss, stats.updates as u64)
            },
        )
    });
    let opt_losses: Vec<f64> = opt_results.iter().map(|r| r.0).collect();
    let updates: u64 = opt_results.iter().map(|r| r.1).sum();
    assert_eq!(
        baseline_losses, opt_losses,
        "optimized engine changed sweep results"
    );

    let runs = jobs.len();

    // batched-seed phases: the IDENTICAL job list, grouped per lane
    // width. grouped_losses flattens point-major in seed order — the
    // same flat order as `jobs` — so plain Vec equality is the bitwise
    // per-run loss assertion.
    let refs: Vec<&ScenarioRunner> = grid.iter().map(|_| &runner).collect();
    let lanes: Vec<LaneBenchRow> = LANE_WIDTHS
        .iter()
        .map(|&width| {
            let (lane_losses, lane_allocs, secs) = timed(|| {
                grouped_losses(&refs, cfg.seeds, threads, width, |p, s| {
                    per_seed(&base, grid[p], s)
                })
                .expect("bench sweep run failed")
            });
            assert_eq!(
                opt_losses, lane_losses,
                "batched engine (L={width}) changed sweep results"
            );
            LaneBenchRow {
                lanes: width,
                secs,
                runs_per_sec: runs as f64 / secs,
                updates_per_sec: updates as f64 / secs,
                speedup: optimized_secs / secs,
                allocs_per_run: allocs_per_unit(lane_allocs, runs),
            }
        })
        .collect();

    // sharded-DES device-count scaling: the same k-device scenario,
    // inline single-shard event loop vs SCALING_SHARDS shard workers;
    // counts the train split can't populate are skipped
    let device_scaling: Vec<DeviceScalingRow> = cfg
        .devices
        .iter()
        .copied()
        .filter(|&k| k >= 2 && k <= train.n)
        .map(|k| device_scaling_row(&train, k, cfg.n_o))
        .collect();

    let per_run = |allocs: Option<u64>| allocs_per_unit(allocs, runs);
    SweepBenchReport {
        n_train: train.n,
        d: train.d,
        grid,
        seeds: cfg.seeds,
        threads,
        runs,
        updates,
        baseline_secs,
        optimized_secs,
        baseline_runs_per_sec: runs as f64 / baseline_secs,
        runs_per_sec: runs as f64 / optimized_secs,
        speedup: baseline_secs / optimized_secs,
        updates_per_sec: updates as f64 / optimized_secs,
        allocs_per_run_baseline: per_run(baseline_allocs),
        allocs_per_run: per_run(opt_allocs),
        lanes,
        device_scaling,
    }
}

/// Measure one device-count point of the sharded-DES scaling phase:
/// the identical k-device scenario (round-robin shards, one ideal
/// channel lane per device, greedy scheduler) run at 1 shard and at
/// [`SCALING_SHARDS`], asserted bit-identical before timing.
fn device_scaling_row(
    train: &Dataset,
    k: usize,
    n_o: f64,
) -> DeviceScalingRow {
    let shards_ds = shard_round_robin(train, k);
    let slowdowns = vec![1.0; k];
    // small blocks keep per-device draws tiny (the fleet regime the
    // sharded loop targets); the budget is effectively unbounded so
    // every run ends by source exhaustion, not by deadline
    let dcfg = DesConfig { n_c: 4, ..bench_base(n_o, 1e12) };
    let mut run = |n_shards: usize| {
        let mut source = ShardedSource::new(
            &shards_ds,
            dcfg.seed,
            GreedyScheduler::new(),
            &slowdowns,
            n_shards,
        );
        let mut policy = FixedPolicy(dcfg.n_c);
        let mut channel = MultiLaneChannel::uniform(k, |_| IdealChannel);
        let mut exec = NativeExecutor::new(
            RidgeModel::new(train.d, dcfg.lambda, train.n),
            dcfg.alpha,
        );
        let t0 = Instant::now();
        let res = run_schedule(
            train,
            &dcfg,
            &mut source,
            &mut policy,
            OverlapMode::Pipelined,
            &mut channel,
            &mut exec,
        )
        .expect("device-scaling run failed");
        (res, t0.elapsed().as_secs_f64())
    };
    let (single, secs_single) = run(1);
    let (sharded, secs_sharded) = run(SCALING_SHARDS);
    assert_eq!(
        single.final_loss.to_bits(),
        sharded.final_loss.to_bits(),
        "sharded DES (k={k}) changed the final loss"
    );
    assert_eq!(single.updates, sharded.updates, "k={k} update count");
    assert_eq!(
        single.samples_delivered, sharded.samples_delivered,
        "k={k} samples delivered"
    );
    DeviceScalingRow {
        devices: k,
        shards: SCALING_SHARDS.min(k),
        secs_single,
        secs_sharded,
        speedup: secs_single / secs_sharded,
        samples: single.samples_delivered,
    }
}

fn timed<R>(f: impl FnOnce() -> R) -> (R, Option<u64>, f64) {
    let t0 = Instant::now();
    let (out, allocs) = allocations_during(f);
    (out, allocs, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_bench_runs_and_phases_agree() {
        // the loss-equality assertion inside run_sweep_bench is the
        // real check; keep the workload tiny
        let report = run_sweep_bench(&SweepBenchConfig {
            n: 400,
            grid_points: 3,
            seeds: 2,
            threads: 2,
            n_o: 5.0,
            // 12 devices → ~30 samples each; 1000 exceeds the train
            // split and must be skipped, not crash the bench
            devices: vec![12, 1000],
        });
        assert_eq!(report.runs, report.grid.len() * 2);
        assert!(report.updates > 0);
        assert!(report.runs_per_sec > 0.0);
        assert!(report.baseline_runs_per_sec > 0.0);
        // one batched row per supported lane width, all measured
        assert_eq!(report.lanes.len(), LANE_WIDTHS.len());
        for (row, &width) in report.lanes.iter().zip(LANE_WIDTHS.iter()) {
            assert_eq!(row.lanes, width);
            assert!(row.secs > 0.0 && row.runs_per_sec > 0.0);
            assert!(row.speedup.is_finite() && row.speedup > 0.0);
        }
        assert_eq!(report.widest_lane_row().unwrap().lanes, 16);
        // the oversize device count is skipped; the in-range one is
        // measured (the bitwise identity assertion lives inside
        // device_scaling_row)
        assert_eq!(report.device_scaling.len(), 1);
        let row = &report.device_scaling[0];
        assert_eq!(row.devices, 12);
        assert_eq!(row.shards, SCALING_SHARDS);
        assert!(row.secs_single > 0.0 && row.secs_sharded > 0.0);
        assert!(row.speedup.is_finite() && row.speedup > 0.0);
        assert!(row.samples > 0);
        // JSON round-trips at schema 3 with lane + device-scaling rows
        let v = report.to_value();
        assert_eq!(
            v.get("runs").unwrap().as_usize().unwrap(),
            report.runs
        );
        assert_eq!(v.get("schema").unwrap().as_usize().unwrap(), 3);
        let rows = v.get("lanes").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), LANE_WIDTHS.len());
        assert_eq!(
            rows[2].get("lanes").unwrap().as_usize().unwrap(),
            16
        );
        let scaling = v.get("device_scaling").unwrap().as_arr().unwrap();
        assert_eq!(scaling.len(), 1);
        assert_eq!(
            scaling[0].get("devices").unwrap().as_usize().unwrap(),
            12
        );
        assert!(report.render().contains("speedup"));
        assert!(report.render().contains("batched L=16"));
        assert!(report.render().contains("devices k=12"));
    }
}

