//! The native single-sample SGD executor (paper eq. (2)).

use crate::model::PointModel;
use crate::util::rng::Pcg32;

/// A borrowed view of the edge node's sample store: flat row-major
/// covariates plus labels. The store only ever grows (paper Sec. 2:
/// `X̃_{b+1} = X̃_b ∪ X_b`), so a `(ptr, len)` view taken at block start
/// stays valid for the whole block.
#[derive(Clone, Copy, Debug)]
pub struct StoreView<'a> {
    pub x: &'a [f32],
    pub y: &'a [f32],
    pub d: usize,
}

impl<'a> StoreView<'a> {
    pub fn new(x: &'a [f32], y: &'a [f32], d: usize) -> StoreView<'a> {
        assert_eq!(x.len(), y.len() * d, "store shape mismatch");
        StoreView { x, y, d }
    }

    /// Number of samples in view.
    #[inline]
    pub fn len(&self) -> usize {
        self.y.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Row `i` covariates.
    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }
}

/// The native SGD engine. Stateless apart from the learning rate; sampling
/// randomness is supplied per call so the coordinator controls streams.
#[derive(Clone, Debug)]
pub struct SgdEngine {
    /// Learning rate α (paper: 1e-4).
    pub alpha: f64,
}

impl SgdEngine {
    pub fn new(alpha: f64) -> SgdEngine {
        SgdEngine { alpha }
    }

    /// Run `n_updates` single-sample SGD updates on `w`, drawing ξ i.i.d.
    /// uniform from `store` (paper eq. (2)). Returns the indices drawn
    /// count (== n_updates) for accounting.
    pub fn run_updates<M: PointModel>(
        &self,
        model: &M,
        w: &mut [f64],
        store: StoreView<'_>,
        n_updates: usize,
        rng: &mut Pcg32,
    ) -> usize {
        assert!(!store.is_empty(), "SGD on an empty store");
        let n = store.len() as u64;
        for _ in 0..n_updates {
            let i = rng.gen_range(n) as usize;
            model.sgd_step(w, store.row(i), store.y[i], self.alpha);
        }
        n_updates
    }

    /// Like [`run_updates`](Self::run_updates) but records the chosen
    /// sample indices (used by parity tests: the same index sequence
    /// must produce the same trajectory on every execution path).
    pub fn run_updates_traced<M: PointModel>(
        &self,
        model: &M,
        w: &mut [f64],
        store: StoreView<'_>,
        n_updates: usize,
        rng: &mut Pcg32,
        trace: &mut Vec<u32>,
    ) -> usize {
        assert!(!store.is_empty(), "SGD on an empty store");
        let n = store.len() as u64;
        trace.reserve(n_updates);
        for _ in 0..n_updates {
            let i = rng.gen_range(n) as usize;
            trace.push(i as u32);
            model.sgd_step(w, store.row(i), store.y[i], self.alpha);
        }
        n_updates
    }

    /// Replay updates for an explicit index sequence (deterministic).
    pub fn run_indices<M: PointModel>(
        &self,
        model: &M,
        w: &mut [f64],
        store: StoreView<'_>,
        indices: &[u32],
    ) {
        for &i in indices {
            let i = i as usize;
            model.sgd_step(w, store.row(i), store.y[i], self.alpha);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::RidgeModel;

    fn small_store() -> (Vec<f32>, Vec<f32>) {
        // 4 samples in R^2 from w_true = [1, -1], no noise
        let x = vec![1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0, 2.0, -1.0];
        let y = vec![1.0f32, -1.0, 0.0, 3.0];
        (x, y)
    }

    #[test]
    fn converges_to_ground_truth() {
        let (x, y) = small_store();
        let store = StoreView::new(&x, &y, 2);
        let model = RidgeModel::new(2, 0.0, 4);
        let engine = SgdEngine::new(0.05);
        let mut w = vec![0.0, 0.0];
        let mut rng = Pcg32::seeded(3);
        engine.run_updates(&model, &mut w, store, 5000, &mut rng);
        assert!((w[0] - 1.0).abs() < 1e-3, "w = {w:?}");
        assert!((w[1] + 1.0).abs() < 1e-3, "w = {w:?}");
    }

    #[test]
    fn traced_equals_untraced() {
        let (x, y) = small_store();
        let store = StoreView::new(&x, &y, 2);
        let model = RidgeModel::new(2, 0.01, 4);
        let engine = SgdEngine::new(0.02);
        let mut w1 = vec![0.5, -0.5];
        let mut w2 = w1.clone();
        let mut trace = Vec::new();
        engine.run_updates(&model, &mut w1, store, 100, &mut Pcg32::seeded(9));
        engine.run_updates_traced(
            &model, &mut w2, store, 100, &mut Pcg32::seeded(9), &mut trace,
        );
        assert_eq!(w1, w2);
        assert_eq!(trace.len(), 100);
    }

    #[test]
    fn replay_matches_trace() {
        let (x, y) = small_store();
        let store = StoreView::new(&x, &y, 2);
        let model = RidgeModel::new(2, 0.01, 4);
        let engine = SgdEngine::new(0.02);
        let mut w1 = vec![0.1, 0.2];
        let mut trace = Vec::new();
        engine.run_updates_traced(
            &model, &mut w1, store, 64, &mut Pcg32::seeded(4), &mut trace,
        );
        let mut w2 = vec![0.1, 0.2];
        engine.run_indices(&model, &mut w2, store, &trace);
        assert_eq!(w1, w2);
    }

    #[test]
    #[should_panic]
    fn empty_store_panics() {
        let x: Vec<f32> = vec![];
        let y: Vec<f32> = vec![];
        let store = StoreView::new(&x, &y, 2);
        let model = RidgeModel::new(2, 0.0, 1);
        SgdEngine::new(0.1).run_updates(
            &model,
            &mut [0.0, 0.0],
            store,
            1,
            &mut Pcg32::seeded(0),
        );
    }
}
