//! Native SGD engine: the f64 oracle / fast-sweep backend.
//!
//! The PJRT path ([`crate::runtime`]) is the "production" executor; this
//! native engine exists to (i) cross-validate the artifacts bit-for-bit at
//! f32 tolerance, and (ii) run the wide Monte-Carlo sweeps behind Fig. 3/4
//! at tens of millions of updates per second.

pub mod engine;

pub use engine::{SgdEngine, StoreView};
