//! Native SGD engine: the f64 oracle / fast-sweep backend.
//!
//! The arithmetic oracle for every other execution path: the threaded
//! pipeline, the golden traces, and the batched-seed sweep engine
//! (`sweep/batch.rs`) all cross-validate against it bit-for-bit. Runs
//! the wide Monte-Carlo sweeps behind Fig. 3/4 at tens of millions of
//! updates per second.

pub mod engine;

pub use engine::{SgdEngine, StoreView};
