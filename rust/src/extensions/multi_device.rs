//! Multiple devices sharing the uplink (paper Sec. 6).
//!
//! `k` devices each hold a disjoint shard of the dataset and transmit in
//! round-robin over the shared channel; each device pays its own packet
//! overhead. The edge node trains on the union store exactly as in the
//! single-device protocol. With the channel serialized, total overhead
//! grows with the number of active devices — so the per-device optimal
//! block size shifts upward (the multi_device example shows this).

use anyhow::Result;

use crate::channel::Channel;
use crate::coordinator::des::{DesConfig, EdgeTrainer};
use crate::coordinator::events::EventLog;
use crate::coordinator::executor::BlockExecutor;
use crate::coordinator::run::RunResult;
use crate::data::Dataset;
use crate::protocol::TimelineCase;
use crate::util::rng::Pcg32;

/// Shard `ds` into `k` near-equal disjoint shards (round-robin rows).
pub fn shard_dataset(ds: &Dataset, k: usize) -> Vec<Dataset> {
    assert!(k >= 1 && k <= ds.n, "bad shard count");
    (0..k)
        .map(|s| {
            let idx: Vec<usize> =
                (s..ds.n).step_by(k).collect();
            ds.subset(&idx)
        })
        .collect()
}

/// Per-device transmitter state for the round-robin schedule.
struct DeviceState {
    remaining: Vec<u32>,
    rng: Pcg32,
}

/// Run the multi-device protocol: devices take turns sending blocks of
/// `n_c` of their own (unsent) samples; the edge trains continuously.
pub fn run_multi_device(
    ds: &Dataset,
    shards: &[Dataset],
    cfg: &DesConfig,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let mut events = EventLog::with_capacity(cfg.event_capacity);
    let mut trainer = EdgeTrainer::new(ds, cfg);
    let mut chan_rng =
        Pcg32::new(cfg.seed, crate::coordinator::des::STREAM_CHANNEL);
    let mut devices: Vec<DeviceState> = shards
        .iter()
        .enumerate()
        .map(|(i, shard)| DeviceState {
            remaining: (0..shard.n as u32).collect(),
            rng: Pcg32::new(cfg.seed.wrapping_add(1000 + i as u64), 2),
        })
        .collect();

    let mut t_send = 0.0;
    let mut turn = 0usize;
    let mut block = 1usize;
    let (mut blocks_sent, mut blocks_delivered) = (0usize, 0usize);
    let mut samples_delivered = 0usize;
    let mut retransmissions = 0u64;

    while t_send < cfg.t_budget
        && devices.iter().any(|d| !d.remaining.is_empty())
    {
        // next device with data, round-robin
        while devices[turn % devices.len()].remaining.is_empty() {
            turn += 1;
        }
        let dev_id = turn % devices.len();
        let shard = &shards[dev_id];
        let dev = &mut devices[dev_id];
        turn += 1;

        // sample without replacement from this device's shard
        let k = cfg.n_c.min(dev.remaining.len());
        let len = dev.remaining.len();
        for i in 0..k {
            let j = dev.rng.gen_range((len - i) as u64) as usize;
            dev.remaining.swap(j, len - 1 - i);
        }
        let chosen: Vec<u32> = dev.remaining.split_off(len - k);
        let mut x = Vec::with_capacity(k * ds.d);
        let mut y = Vec::with_capacity(k);
        for &i in &chosen {
            x.extend_from_slice(shard.row(i as usize));
            y.push(shard.label(i as usize));
        }

        let duration = k as f64 + cfg.n_o;
        blocks_sent += 1;
        let delivery = channel.transmit(t_send, duration, &mut chan_rng);
        retransmissions += (delivery.attempts - 1) as u64;
        if delivery.arrival < cfg.t_budget {
            trainer.advance_to(delivery.arrival, exec, &mut events)?;
            trainer.ingest_block(block, delivery.arrival, &x, &y);
            blocks_delivered += 1;
            samples_delivered += k;
        } else {
            trainer.advance_to(cfg.t_budget, exec, &mut events)?;
        }
        t_send = delivery.arrival;
        block += 1;
    }
    trainer.advance_to(cfg.t_budget, exec, &mut events)?;
    trainer.finish(exec)?;

    let case = if samples_delivered >= ds.n {
        TimelineCase::Full
    } else {
        TimelineCase::Partial
    };
    let final_loss = trainer.full_loss();
    Ok(RunResult {
        curve: trainer.curve,
        final_loss,
        final_w: trainer.w,
        updates: trainer.updates,
        blocks_sent,
        blocks_delivered,
        samples_delivered,
        retransmissions,
        case,
        snapshots: trainer.snapshots,
        events: events.into_events(),
        backend: exec.name(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;

    #[test]
    fn shards_are_disjoint_and_cover() {
        let ds =
            synth_calhousing(&SynthSpec { n: 103, ..Default::default() });
        let shards = shard_dataset(&ds, 4);
        assert_eq!(shards.len(), 4);
        let total: usize = shards.iter().map(|s| s.n).sum();
        assert_eq!(total, ds.n);
        // sizes near-equal
        for s in &shards {
            assert!((s.n as i64 - 103 / 4).abs() <= 1);
        }
    }

    #[test]
    fn multi_device_trains_and_delivers() {
        let ds =
            synth_calhousing(&SynthSpec { n: 600, ..Default::default() });
        let shards = shard_dataset(&ds, 3);
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(50, 10.0, 1500.0, 6)
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res = run_multi_device(
            &ds,
            &shards,
            &cfg,
            &mut IdealChannel,
            &mut exec,
        )
        .unwrap();
        assert_eq!(res.samples_delivered, ds.n);
        assert!(res.final_loss < res.curve[0].1);
        assert_eq!(res.case, TimelineCase::Full);
    }

    #[test]
    fn single_shard_reduces_to_multi_of_one() {
        // k=1 multi-device must behave like a (differently-seeded) run:
        // same delivery counts for the same schedule.
        let ds =
            synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let shards = shard_dataset(&ds, 1);
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(30, 5.0, 600.0, 6)
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res = run_multi_device(
            &ds,
            &shards,
            &cfg,
            &mut IdealChannel,
            &mut exec,
        )
        .unwrap();
        assert_eq!(res.blocks_sent, 300 / 30);
    }
}
