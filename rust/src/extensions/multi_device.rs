//! Multiple devices sharing the uplink (paper Sec. 6).
//!
//! `k` devices each hold a disjoint shard of the dataset and transmit in
//! round-robin over the shared channel; each device pays its own packet
//! overhead. The edge node trains on the union store exactly as in the
//! single-device protocol. With the channel serialized, total overhead
//! grows with the number of active devices — so the per-device optimal
//! block size shifts upward (the multi_device example shows this).
//!
//! The run itself is a thin adapter: [`RoundRobinSource`] feeding the
//! generic scheduler under the fixed-`n_c` policy. Device 0's RNG stream
//! equals the single-device stream, so `k = 1` is bit-identical to
//! [`run_des`](crate::coordinator::des::run_des) (asserted in
//! `rust/tests/scenario_parity.rs`).

use anyhow::Result;

use crate::channel::Channel;
use crate::coordinator::des::DesConfig;
use crate::coordinator::executor::BlockExecutor;
use crate::coordinator::run::RunResult;
use crate::coordinator::scheduler::{
    run_schedule, FixedPolicy, OverlapMode, RoundRobinSource,
};
use crate::data::Dataset;

/// Shard `ds` into `k` near-equal disjoint shards (round-robin rows:
/// shard `s` holds dataset rows `s, s+k, s+2k, ...` in that order).
pub fn shard_dataset(ds: &Dataset, k: usize) -> Vec<Dataset> {
    assert!(k >= 1 && k <= ds.n, "bad shard count");
    (0..k)
        .map(|s| {
            let idx: Vec<usize> =
                (s..ds.n).step_by(k).collect();
            ds.subset(&idx)
        })
        .collect()
}

/// Run the multi-device protocol: devices take turns sending blocks of
/// `n_c` of their own (unsent) samples; the edge trains continuously.
pub fn run_multi_device(
    ds: &Dataset,
    shards: &[Dataset],
    cfg: &DesConfig,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let mut source = RoundRobinSource::new(shards, cfg.seed);
    let mut policy = FixedPolicy(cfg.n_c.max(1));
    run_schedule(
        ds,
        cfg,
        &mut source,
        &mut policy,
        OverlapMode::Pipelined,
        channel,
        exec,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;
    use crate::protocol::TimelineCase;

    #[test]
    fn shards_are_disjoint_and_cover() {
        let ds =
            synth_calhousing(&SynthSpec { n: 103, ..Default::default() });
        let shards = shard_dataset(&ds, 4);
        assert_eq!(shards.len(), 4);
        let total: usize = shards.iter().map(|s| s.n).sum();
        assert_eq!(total, ds.n);
        // sizes near-equal
        for s in &shards {
            assert!((s.n as i64 - 103 / 4).abs() <= 1);
        }
    }

    #[test]
    fn multi_device_trains_and_delivers() {
        let ds =
            synth_calhousing(&SynthSpec { n: 600, ..Default::default() });
        let shards = shard_dataset(&ds, 3);
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(50, 10.0, 1500.0, 6)
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res = run_multi_device(
            &ds,
            &shards,
            &cfg,
            &mut IdealChannel,
            &mut exec,
        )
        .unwrap();
        assert_eq!(res.samples_delivered, ds.n);
        assert!(res.final_loss < res.curve[0].1);
        assert_eq!(res.case, TimelineCase::Full);
    }

    #[test]
    fn single_shard_reduces_to_multi_of_one() {
        // k=1 multi-device must behave like the single-device run:
        // same delivery counts for the same schedule.
        let ds =
            synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let shards = shard_dataset(&ds, 1);
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(30, 5.0, 600.0, 6)
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res = run_multi_device(
            &ds,
            &shards,
            &cfg,
            &mut IdealChannel,
            &mut exec,
        )
        .unwrap();
        assert_eq!(res.blocks_sent, 300 / 30);
    }
}
