//! Multiple devices sharing the uplink (paper Sec. 6).
//!
//! `k` devices each hold a disjoint shard of the dataset and transmit in
//! round-robin over the shared channel; each device pays its own packet
//! overhead. The edge node trains on the union store exactly as in the
//! single-device protocol. With the channel serialized, total overhead
//! grows with the number of active devices — so the per-device optimal
//! block size shifts upward (the multi_device example shows this).
//!
//! The run itself is a thin adapter: [`RoundRobinSource`] feeding the
//! generic scheduler under the fixed-`n_c` policy. Device 0's RNG stream
//! equals the single-device stream, so `k = 1` is bit-identical to
//! [`run_des`](crate::coordinator::des::run_des) (asserted in
//! `rust/tests/scenario_parity.rs`).

use anyhow::Result;

use crate::channel::Channel;
use crate::coordinator::des::DesConfig;
use crate::coordinator::executor::BlockExecutor;
use crate::coordinator::run::RunResult;
use crate::coordinator::scheduler::{
    run_schedule, DeviceScheduler, FixedPolicy, OverlapMode,
    RoundRobinSource, ScheduledSource,
};
use crate::data::Dataset;

pub use crate::data::shard::{shard_label_skew, shard_round_robin};

/// Shard `ds` into `k` near-equal disjoint shards (round-robin rows:
/// shard `s` holds dataset rows `s, s+k, s+2k, ...` in that order).
/// Alias of [`crate::data::shard::shard_round_robin`]; the non-IID
/// label-skew layout lives next to it ([`shard_label_skew`]).
pub fn shard_dataset(ds: &Dataset, k: usize) -> Vec<Dataset> {
    shard_round_robin(ds, k)
}

/// Run the heterogeneous multi-device protocol: a [`DeviceScheduler`]
/// picks the transmitting device each block, each device draws its own
/// samples (stream seed `+1000·i`), and `channel` carries every block —
/// pass a [`MultiLaneChannel`](crate::channel::MultiLaneChannel) to give
/// each device its own link (the scheduler core routes blocks to the
/// transmitting device's lane). `slowdowns[i]` is device `i`'s expected
/// link slowdown, the signal the greedy/proportional-fair schedulers
/// rank lanes by (all-ones for a homogeneous uplink).
pub fn run_scheduled_devices<S: DeviceScheduler>(
    ds: &Dataset,
    shards: &[Dataset],
    slowdowns: &[f64],
    cfg: &DesConfig,
    sched: S,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let mut source =
        ScheduledSource::new(shards, cfg.seed, sched, slowdowns);
    let mut policy = FixedPolicy(cfg.n_c.max(1));
    run_schedule(
        ds,
        cfg,
        &mut source,
        &mut policy,
        OverlapMode::Pipelined,
        channel,
        exec,
    )
}

/// Run the multi-device protocol: devices take turns sending blocks of
/// `n_c` of their own (unsent) samples; the edge trains continuously.
pub fn run_multi_device(
    ds: &Dataset,
    shards: &[Dataset],
    cfg: &DesConfig,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let mut source = RoundRobinSource::new(shards, cfg.seed);
    let mut policy = FixedPolicy(cfg.n_c.max(1));
    run_schedule(
        ds,
        cfg,
        &mut source,
        &mut policy,
        OverlapMode::Pipelined,
        channel,
        exec,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;
    use crate::protocol::TimelineCase;

    #[test]
    fn shards_are_disjoint_and_cover() {
        let ds =
            synth_calhousing(&SynthSpec { n: 103, ..Default::default() });
        let shards = shard_dataset(&ds, 4);
        assert_eq!(shards.len(), 4);
        let total: usize = shards.iter().map(|s| s.n).sum();
        assert_eq!(total, ds.n);
        // sizes near-equal
        for s in &shards {
            assert!((s.n as i64 - 103 / 4).abs() <= 1);
        }
    }

    #[test]
    fn multi_device_trains_and_delivers() {
        let ds =
            synth_calhousing(&SynthSpec { n: 600, ..Default::default() });
        let shards = shard_dataset(&ds, 3);
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(50, 10.0, 1500.0, 6)
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res = run_multi_device(
            &ds,
            &shards,
            &cfg,
            &mut IdealChannel,
            &mut exec,
        )
        .unwrap();
        assert_eq!(res.samples_delivered, ds.n);
        assert!(res.final_loss < res.curve[0].1);
        assert_eq!(res.case, TimelineCase::Full);
    }

    #[test]
    fn scheduled_round_robin_matches_run_multi_device() {
        use crate::channel::MultiLaneChannel;
        use crate::coordinator::scheduler::RoundRobinScheduler;
        // homogeneous lanes + round-robin scheduling == the legacy
        // shared-channel round-robin run, bit for bit
        let ds =
            synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let shards = shard_dataset(&ds, 3);
        let cfg = DesConfig {
            alpha: 1e-3,
            event_capacity: 4096,
            ..DesConfig::paper(25, 5.0, 900.0, 17)
        };
        let mut exec_a = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let legacy = run_multi_device(
            &ds,
            &shards,
            &cfg,
            &mut IdealChannel,
            &mut exec_a,
        )
        .unwrap();
        let mut exec_b = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let mut lanes = MultiLaneChannel::new(vec![
            IdealChannel,
            IdealChannel,
            IdealChannel,
        ]);
        let sched = run_scheduled_devices(
            &ds,
            &shards,
            &[1.0, 1.0, 1.0],
            &cfg,
            RoundRobinScheduler::new(),
            &mut lanes,
            &mut exec_b,
        )
        .unwrap();
        assert_eq!(legacy.final_w, sched.final_w);
        assert_eq!(legacy.events, sched.events);
        assert_eq!(legacy.updates, sched.updates);
    }

    #[test]
    fn single_shard_reduces_to_multi_of_one() {
        // k=1 multi-device must behave like the single-device run:
        // same delivery counts for the same schedule.
        let ds =
            synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let shards = shard_dataset(&ds, 1);
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(30, 5.0, 600.0, 6)
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res = run_multi_device(
            &ds,
            &shards,
            &cfg,
            &mut IdealChannel,
            &mut exec,
        )
        .unwrap();
        assert_eq!(res.blocks_sent, 300 / 30);
    }
}
