//! Online / limited-memory edge learning (paper Sec. 6).
//!
//! The edge node can only store `capacity` samples; older samples are
//! evicted by reservoir sampling (the store then always holds a uniform
//! subsample of everything received). The question the ablation bench
//! answers: how much final loss does a memory budget cost, and does the
//! optimal block size shift?

use anyhow::Result;

use crate::channel::Channel;
use crate::coordinator::des::{run_des, DesConfig};
use crate::coordinator::executor::BlockExecutor;
use crate::coordinator::run::RunResult;
use crate::data::Dataset;

/// Run the protocol with a bounded edge store.
pub fn run_online(
    ds: &Dataset,
    cfg: &DesConfig,
    capacity: usize,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let cfg = DesConfig { store_capacity: Some(capacity), ..cfg.clone() };
    run_des(ds, &cfg, channel, exec)
}

/// Sweep final loss across store capacities (the Abl-4 producer).
pub fn capacity_sweep(
    ds: &Dataset,
    cfg: &DesConfig,
    capacities: &[usize],
    seeds: usize,
) -> Vec<(usize, f64)> {
    use crate::channel::IdealChannel;
    use crate::coordinator::executor::NativeExecutor;
    use crate::model::RidgeModel;
    use crate::util::pool::{default_threads, parallel_map};

    let jobs: Vec<(usize, u64)> = capacities
        .iter()
        .flat_map(|&cap| (0..seeds as u64).map(move |s| (cap, s)))
        .collect();
    let losses = parallel_map(&jobs, default_threads(), |&(cap, s)| {
        let run_cfg = DesConfig {
            store_capacity: Some(cap),
            seed: cfg.seed.wrapping_add(s),
            record_blocks: false,
            ..cfg.clone()
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, run_cfg.lambda, ds.n),
            run_cfg.alpha,
        );
        run_des(ds, &run_cfg, &mut IdealChannel, &mut exec)
            .expect("online run")
            .final_loss
    });
    capacities
        .iter()
        .enumerate()
        .map(|(i, &cap)| {
            let slice = &losses[i * seeds..(i + 1) * seeds];
            (cap, slice.iter().sum::<f64>() / seeds as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;

    #[test]
    fn bounded_store_respects_capacity() {
        let ds =
            synth_calhousing(&SynthSpec { n: 500, ..Default::default() });
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(50, 5.0, 900.0, 4)
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res =
            run_online(&ds, &cfg, 100, &mut IdealChannel, &mut exec).unwrap();
        // all samples were DELIVERED even though only 100 are stored
        assert_eq!(res.samples_delivered, ds.n);
        assert!(res.final_loss.is_finite());
    }

    #[test]
    fn more_memory_is_no_worse_on_average() {
        let ds =
            synth_calhousing(&SynthSpec { n: 400, ..Default::default() });
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(40, 5.0, 800.0, 9)
        };
        let rows = capacity_sweep(&ds, &cfg, &[20, 400], 6);
        assert_eq!(rows.len(), 2);
        let (tiny, full) = (rows[0].1, rows[1].1);
        assert!(
            full <= tiny * 1.05,
            "full memory {full} should not lose to capacity-20 {tiny}"
        );
    }
}
