//! Online / limited-memory edge learning (paper Sec. 6).
//!
//! Two orthogonal "online" axes, both served by the generic scheduler:
//!
//! * **Bounded edge memory** — the edge can only store `capacity`
//!   samples; older samples are evicted by reservoir sampling (the store
//!   then always holds a uniform subsample of everything received).
//!   [`run_online`] / [`capacity_sweep`] answer: how much final loss does
//!   a memory budget cost, and does the optimal block size shift?
//! * **Streaming arrivals** — the *device* does not hold the dataset up
//!   front either; samples arrive at `rate` per time unit and are
//!   forwarded greedily ([`run_online_arrivals`], built on
//!   [`OnlineArrivalSource`](crate::coordinator::OnlineArrivalSource)).

use anyhow::Result;

use crate::channel::Channel;
use crate::coordinator::des::{run_des, DesConfig};
use crate::coordinator::executor::BlockExecutor;
use crate::coordinator::run::RunResult;
use crate::coordinator::scheduler::{
    run_schedule, FixedPolicy, OnlineArrivalSource, OverlapMode,
};
use crate::data::Dataset;

/// Run the protocol with a bounded edge store.
pub fn run_online(
    ds: &Dataset,
    cfg: &DesConfig,
    capacity: usize,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let cfg = DesConfig { store_capacity: Some(capacity), ..cfg.clone() };
    run_des(ds, &cfg, channel, exec)
}

/// Run the protocol when device samples arrive over time at `rate`
/// samples per normalized time unit (`f64::INFINITY` recovers the
/// standard all-data-up-front protocol bit-for-bit).
pub fn run_online_arrivals(
    ds: &Dataset,
    cfg: &DesConfig,
    rate: f64,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let mut source = OnlineArrivalSource::new(ds, rate, cfg.seed);
    let mut policy = FixedPolicy(cfg.n_c.max(1));
    run_schedule(
        ds,
        cfg,
        &mut source,
        &mut policy,
        OverlapMode::Pipelined,
        channel,
        exec,
    )
}

/// Sweep final loss across store capacities (the Abl-4 producer).
/// One flat `(capacity, seed)` fan-out with per-worker workspaces.
pub fn capacity_sweep(
    ds: &Dataset,
    cfg: &DesConfig,
    capacities: &[usize],
    seeds: usize,
) -> Vec<(usize, f64)> {
    use crate::coordinator::scheduler::RunWorkspace;
    use crate::sweep::scenario::{ScenarioRunner, ScenarioSpec};
    use crate::util::pool::{default_threads, parallel_map_with};

    let runner = ScenarioRunner::new(ScenarioSpec::paper(), ds);
    let jobs: Vec<(usize, u64)> = capacities
        .iter()
        .flat_map(|&cap| (0..seeds as u64).map(move |s| (cap, s)))
        .collect();
    let losses = parallel_map_with(
        &jobs,
        default_threads(),
        RunWorkspace::new,
        |ws, &(cap, s)| {
            let run_cfg = DesConfig {
                store_capacity: Some(cap),
                seed: cfg.seed.wrapping_add(s),
                record_blocks: false,
                ..cfg.clone()
            };
            runner.run_with(ws, &run_cfg).expect("online run").final_loss
        },
    );
    capacities
        .iter()
        .enumerate()
        .map(|(i, &cap)| {
            let slice = &losses[i * seeds..(i + 1) * seeds];
            (cap, slice.iter().sum::<f64>() / seeds as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;

    #[test]
    fn bounded_store_respects_capacity() {
        let ds =
            synth_calhousing(&SynthSpec { n: 500, ..Default::default() });
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(50, 5.0, 900.0, 4)
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res =
            run_online(&ds, &cfg, 100, &mut IdealChannel, &mut exec).unwrap();
        // all samples were DELIVERED even though only 100 are stored
        assert_eq!(res.samples_delivered, ds.n);
        assert!(res.final_loss.is_finite());
    }

    #[test]
    fn more_memory_is_no_worse_on_average() {
        let ds =
            synth_calhousing(&SynthSpec { n: 400, ..Default::default() });
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(40, 5.0, 800.0, 9)
        };
        let rows = capacity_sweep(&ds, &cfg, &[20, 400], 6);
        assert_eq!(rows.len(), 2);
        let (tiny, full) = (rows[0].1, rows[1].1);
        assert!(
            full <= tiny * 1.05,
            "full memory {full} should not lose to capacity-20 {tiny}"
        );
    }

    #[test]
    fn instant_arrivals_match_run_des() {
        let ds =
            synth_calhousing(&SynthSpec { n: 350, ..Default::default() });
        let cfg = DesConfig {
            alpha: 1e-3,
            record_blocks: false,
            ..DesConfig::paper(35, 5.0, 700.0, 12)
        };
        let mk = || {
            NativeExecutor::new(
                RidgeModel::new(ds.d, cfg.lambda, ds.n),
                cfg.alpha,
            )
        };
        let des =
            run_des(&ds, &cfg, &mut IdealChannel, &mut mk()).unwrap();
        let online = run_online_arrivals(
            &ds,
            &cfg,
            f64::INFINITY,
            &mut IdealChannel,
            &mut mk(),
        )
        .unwrap();
        assert_eq!(des.final_w, online.final_w);
        assert_eq!(des.updates, online.updates);
    }

    #[test]
    fn slower_arrivals_deliver_later() {
        let ds =
            synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let cfg = DesConfig {
            alpha: 1e-3,
            record_blocks: false,
            event_capacity: 1 << 12,
            ..DesConfig::paper(30, 5.0, 3000.0, 3)
        };
        let mk = || {
            NativeExecutor::new(
                RidgeModel::new(ds.d, cfg.lambda, ds.n),
                cfg.alpha,
            )
        };
        let fast = run_online_arrivals(
            &ds,
            &cfg,
            10.0,
            &mut IdealChannel,
            &mut mk(),
        )
        .unwrap();
        let slow = run_online_arrivals(
            &ds,
            &cfg,
            0.2,
            &mut IdealChannel,
            &mut mk(),
        )
        .unwrap();
        assert_eq!(fast.samples_delivered, ds.n);
        assert_eq!(slow.samples_delivered, ds.n);
        // the slow stream finishes delivering strictly later
        let last_delivery = |r: &RunResult| {
            r.events
                .iter()
                .filter_map(|e| match e.kind {
                    crate::coordinator::EventKind::BlockDelivered {
                        ..
                    } => Some(e.t),
                    _ => None,
                })
                .fold(0.0f64, f64::max)
        };
        assert!(last_delivery(&slow) > last_delivery(&fast));
    }
}
