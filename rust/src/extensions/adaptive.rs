//! Adaptive block-size schedules — beyond the paper's fixed `n_c`.
//!
//! The bound analysis fixes one `n_c` for the whole run, but nothing in
//! the protocol requires that. Intuition from the paper's own trade-off:
//! the FIRST blocks should be small (the edge node idles until the first
//! delivery, so time-to-first-sample dominates early), while LATER blocks
//! should be large (amortize the overhead once the store is rich). This
//! module implements the schedules as [`BlockPolicy`] implementations for
//! the generic scheduler; the `bench_adaptive` ablation quantifies the
//! gain over the fixed-`ñ_c` optimum.

use anyhow::Result;

use crate::channel::Channel;
use crate::coordinator::des::DesConfig;
use crate::coordinator::executor::BlockExecutor;
use crate::coordinator::run::RunResult;
use crate::coordinator::scheduler::{
    run_schedule, OverlapMode, SingleDeviceSource,
};
use crate::data::Dataset;

/// A per-block payload-size policy (re-exported scheduler trait; the
/// historical name is kept for the schedule implementations below).
pub use crate::coordinator::scheduler::BlockPolicy as BlockSchedule;

/// The paper's fixed schedule (the scheduler's own implementation).
pub use crate::coordinator::scheduler::FixedPolicy as FixedSchedule;

/// Geometric warmup: start at `start`, multiply by `growth` per block,
/// cap at `cap`. `warmup(8, 2.0, ñ_c)` reaches the bound optimum after
/// ~log2(ñ_c/8) blocks.
pub struct WarmupSchedule {
    pub start: usize,
    pub growth: f64,
    pub cap: usize,
    current: f64,
}

impl WarmupSchedule {
    pub fn new(start: usize, growth: f64, cap: usize) -> WarmupSchedule {
        assert!(start >= 1 && growth >= 1.0 && cap >= start);
        WarmupSchedule { start, growth, cap, current: start as f64 }
    }
}

impl BlockSchedule for WarmupSchedule {
    fn next_n_c(&mut self, _b: usize, remaining: usize, _t: f64) -> usize {
        let n_c = (self.current.round() as usize).min(self.cap);
        self.current = (self.current * self.growth).min(self.cap as f64);
        n_c.min(remaining).max(1)
    }

    fn name(&self) -> String {
        format!("warmup({}→{} x{})", self.start, self.cap, self.growth)
    }
}

/// Deadline-aware schedule: always sends the block that (greedily)
/// balances "time until this block is usable" against the remaining
/// budget — small when little time remains, larger when plenty does.
pub struct DeadlineAwareSchedule {
    pub t_budget: f64,
    pub n_o: f64,
    /// Fraction of the remaining budget one block may occupy.
    pub aggressiveness: f64,
}

impl BlockSchedule for DeadlineAwareSchedule {
    fn next_n_c(&mut self, _b: usize, remaining: usize, t_now: f64) -> usize {
        let left = (self.t_budget - t_now).max(0.0);
        let budgeted = (self.aggressiveness * left - self.n_o).max(1.0);
        (budgeted as usize).min(remaining).max(1)
    }

    fn name(&self) -> String {
        format!("deadline-aware({})", self.aggressiveness)
    }
}

/// Run the protocol with a per-block schedule: a single device feeding
/// the generic scheduler under the given policy (reproduces `run_des`
/// exactly under `FixedSchedule`).
pub fn run_scheduled(
    ds: &Dataset,
    cfg: &DesConfig,
    schedule: &mut dyn BlockSchedule,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let mut source = SingleDeviceSource::new(ds, cfg.seed);
    run_schedule(
        ds,
        cfg,
        &mut source,
        schedule,
        OverlapMode::Pipelined,
        channel,
        exec,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::des::run_des;
    use crate::coordinator::events::EventKind;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;

    fn setup(n: usize) -> (Dataset, DesConfig) {
        let ds = synth_calhousing(&SynthSpec { n, ..Default::default() });
        let cfg = DesConfig {
            alpha: 1e-3,
            record_blocks: false,
            ..DesConfig::paper(64, 20.0, 1.5 * n as f64, 9)
        };
        (ds, cfg)
    }

    fn exec(ds: &Dataset, cfg: &DesConfig) -> NativeExecutor {
        NativeExecutor::new(RidgeModel::new(ds.d, cfg.lambda, ds.n), cfg.alpha)
    }

    #[test]
    fn fixed_schedule_reproduces_run_des() {
        let (ds, cfg) = setup(500);
        let des = run_des(&ds, &cfg, &mut IdealChannel, &mut exec(&ds, &cfg))
            .unwrap();
        let mut sched = FixedSchedule(cfg.n_c);
        let adaptive = run_scheduled(
            &ds,
            &cfg,
            &mut sched,
            &mut IdealChannel,
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        assert_eq!(des.final_w, adaptive.final_w);
        assert_eq!(des.updates, adaptive.updates);
        assert_eq!(des.samples_delivered, adaptive.samples_delivered);
    }

    #[test]
    fn warmup_grows_and_caps() {
        let mut s = WarmupSchedule::new(4, 2.0, 64);
        let sizes: Vec<usize> =
            (1..=8).map(|b| s.next_n_c(b, 10_000, 0.0)).collect();
        assert_eq!(sizes, vec![4, 8, 16, 32, 64, 64, 64, 64]);
        // respects the remaining count
        assert_eq!(s.next_n_c(9, 10, 0.0), 10);
    }

    #[test]
    fn deadline_aware_shrinks_near_deadline() {
        let mut s = DeadlineAwareSchedule {
            t_budget: 1000.0,
            n_o: 10.0,
            aggressiveness: 0.2,
        };
        let early = s.next_n_c(1, 100_000, 0.0);
        let late = s.next_n_c(9, 100_000, 900.0);
        assert!(early > late, "{early} vs {late}");
        assert!(late >= 1);
    }

    #[test]
    fn warmup_delivers_everything_eventually() {
        let (ds, mut cfg) = setup(400);
        // generous budget: warmup's extra packets need more channel time
        cfg.t_budget = 4.0 * ds.n as f64;
        let mut sched = WarmupSchedule::new(4, 1.5, 200);
        let run = run_scheduled(
            &ds,
            &cfg,
            &mut sched,
            &mut IdealChannel,
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        assert_eq!(run.samples_delivered, ds.n);
        assert!(run.final_loss.is_finite());
    }

    #[test]
    fn warmup_starts_training_earlier_than_big_fixed() {
        // with a large fixed n_c the edge idles for the whole first
        // block; warmup gets data flowing sooner -> earlier first update
        let (ds, mut cfg) = setup(600);
        cfg.n_c = 300;
        cfg.event_capacity = 4096;
        let fixed = run_des(&ds, &cfg, &mut IdealChannel, &mut exec(&ds, &cfg))
            .unwrap();
        let mut sched = WarmupSchedule::new(8, 2.0, 300);
        let warm = run_scheduled(
            &ds,
            &cfg,
            &mut sched,
            &mut IdealChannel,
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        let first_update_time = |r: &RunResult| {
            r.events
                .iter()
                .find(|e| matches!(e.kind, EventKind::UpdatesRun { .. }))
                .map(|e| e.t)
                .unwrap_or(f64::INFINITY)
        };
        assert!(
            first_update_time(&warm) < first_update_time(&fixed),
            "warmup should start training earlier"
        );
    }
}
