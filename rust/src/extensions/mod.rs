//! The paper's Sec.-6 future-work directions, implemented as first-class
//! features — each a thin policy adapter over the generic
//! [`scheduler`](crate::coordinator::scheduler):
//!
//! * [`online`] — limited edge memory ("data sent in previous packets can
//!   be only partially stored at the server") plus streaming device-side
//!   arrivals via `OnlineArrivalSource`.
//! * [`multi_device`] — several devices share the uplink round-robin
//!   ("a scenario with multiple devices") via `RoundRobinSource`.
//! * [`rate_select`] — choosing the transmission rate on an erasure
//!   channel ("the optimization problem could be generalized to account
//!   for the selection of the data rate").
//! * [`adaptive`] — per-block payload schedules (warmup, deadline-aware)
//!   as `BlockPolicy` implementations, generalizing the fixed `n_c`.

pub mod adaptive;
pub mod multi_device;
pub mod online;
pub mod rate_select;
