//! The paper's Sec.-6 future-work directions, implemented as first-class
//! features:
//!
//! * [`online`] — limited edge memory: the store is a reservoir of
//!   bounded capacity ("data sent in previous packets can be only
//!   partially stored at the server").
//! * [`multi_device`] — several devices share the uplink round-robin
//!   ("a scenario with multiple devices").
//! * [`rate_select`] — choosing the transmission rate on an erasure
//!   channel ("the optimization problem could be generalized to account
//!   for the selection of the data rate").

//! * [`adaptive`] — per-block payload schedules (warmup,
//!   deadline-aware), generalizing the paper's fixed `n_c`.

pub mod adaptive;
pub mod multi_device;
pub mod online;
pub mod rate_select;
