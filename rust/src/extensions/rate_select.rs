//! Transmission-rate selection on a noisy channel (paper Sec. 6).
//!
//! Model: transmitting at relative rate `r` shortens a block to
//! `(n_c + n_o)/r` time units but raises the per-packet erasure
//! probability — we use the standard exponential-in-rate outage model
//! `p(r) = 1 − exp(−κ(r − 1))` for `r ≥ 1` (at the nominal rate the link
//! is clean, pushing rate risks erasures and ARQ retransmission delay).
//! The expected block duration is `(n_c+n_o)/(r(1−p(r)))`, so there is an
//! optimal finite rate; this module scans it jointly with `n_c`.

use crate::channel::{ErasureChannel, RateLimitedChannel};
use crate::coordinator::des::{run_des, DesConfig};
use crate::coordinator::executor::NativeExecutor;
use crate::data::Dataset;
use crate::model::RidgeModel;

/// Outage probability at relative rate `r` with steepness `kappa`.
pub fn outage_probability(r: f64, kappa: f64) -> f64 {
    assert!(r >= 1.0, "rates below nominal are always clean here");
    (1.0 - (-kappa * (r - 1.0)).exp()).clamp(0.0, 0.999)
}

/// Expected effective slowdown of rate `r` (duration multiplier vs the
/// nominal rate): `1 / (r (1 − p(r)))`.
pub fn expected_slowdown(r: f64, kappa: f64) -> f64 {
    1.0 / (r * (1.0 - outage_probability(r, kappa)))
}

/// The rate minimizing the expected slowdown (golden-section scan).
pub fn best_rate(kappa: f64, r_max: f64) -> f64 {
    let mut best = (1.0, expected_slowdown(1.0, kappa));
    let steps = 400;
    for i in 0..=steps {
        let r = 1.0 + (r_max - 1.0) * i as f64 / steps as f64;
        let s = expected_slowdown(r, kappa);
        if s < best.1 {
            best = (r, s);
        }
    }
    best.0
}

/// Average final loss at `(rate, n_c)` over `seeds` Monte-Carlo runs on
/// the rate-limited erasure channel.
pub fn mc_loss_at_rate(
    ds: &Dataset,
    cfg: &DesConfig,
    rate: f64,
    kappa: f64,
    seeds: usize,
) -> f64 {
    let p = outage_probability(rate, kappa);
    let mut total = 0.0;
    for s in 0..seeds {
        let run_cfg = DesConfig {
            seed: cfg.seed.wrapping_add(s as u64),
            record_blocks: false,
            ..cfg.clone()
        };
        let mut channel = RateLimitedChannel::new(
            rate,
            ErasureChannel::new(p),
        );
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, run_cfg.lambda, ds.n),
            run_cfg.alpha,
        );
        total += run_des(ds, &run_cfg, &mut channel, &mut exec)
            .expect("rate run")
            .final_loss;
    }
    total / seeds as f64
}

/// Scan rates, returning `(rate, mean final loss)` rows (Abl producer).
pub fn rate_sweep(
    ds: &Dataset,
    cfg: &DesConfig,
    rates: &[f64],
    kappa: f64,
    seeds: usize,
) -> Vec<(f64, f64)> {
    rates
        .iter()
        .map(|&r| (r, mc_loss_at_rate(ds, cfg, r, kappa, seeds)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::util::rng::Pcg32;

    #[test]
    fn outage_model_shape() {
        assert_eq!(outage_probability(1.0, 2.0), 0.0);
        assert!(outage_probability(2.0, 2.0) > 0.5);
        // slowdown is 1 at nominal, worse at huge rates
        assert!((expected_slowdown(1.0, 2.0) - 1.0).abs() < 1e-12);
        assert!(expected_slowdown(5.0, 2.0) > 1.0);
    }

    #[test]
    fn best_rate_is_interior_for_moderate_kappa() {
        let r = best_rate(0.5, 6.0);
        assert!(r > 1.0 && r < 6.0, "r = {r}");
        // sanity: it really is a minimum vs neighbors
        let s = |x: f64| expected_slowdown(x, 0.5);
        assert!(s(r) <= s(1.0) && s(r) <= s(6.0));
    }

    #[test]
    fn harsher_channel_prefers_lower_rate() {
        let gentle = best_rate(0.2, 8.0);
        let harsh = best_rate(2.0, 8.0);
        assert!(harsh <= gentle, "harsh {harsh} vs gentle {gentle}");
    }

    #[test]
    fn rate_sweep_runs() {
        let ds =
            synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(30, 5.0, 500.0, 2)
        };
        let rows = rate_sweep(&ds, &cfg, &[1.0, 1.5, 3.0], 0.8, 3);
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|(_, l)| l.is_finite()));
        let _ = Pcg32::seeded(0); // keep import used in cfg(test)
    }
}
