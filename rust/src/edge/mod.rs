//! Edge-node state: the growing sample store X̃_b and loss evaluation.

pub mod store;

pub use store::SampleStore;
