//! The edge node's sample store X̃_b (paper Sec. 2).
//!
//! The store grows monotonically (`X̃_{b+1} = X̃_b ∪ X_b`) in the paper's
//! protocol; the online-learning extension (Sec. 6) bounds its capacity
//! with reservoir-style eviction, which is implemented here behind
//! [`SampleStore::with_capacity`].

use crate::sgd::StoreView;
use crate::util::rng::Pcg32;

/// A flat, append-mostly sample store.
#[derive(Clone, Debug)]
pub struct SampleStore {
    x: Vec<f32>,
    y: Vec<f32>,
    d: usize,
    /// Maximum number of samples held (None = unbounded, paper protocol).
    capacity: Option<usize>,
    /// Total samples ever ingested (≥ len when capacity-bound).
    ingested: usize,
}

impl Default for SampleStore {
    /// Placeholder store; [`reset`](SampleStore::reset) before use
    /// (workspace plumbing).
    fn default() -> SampleStore {
        SampleStore::new(0)
    }
}

impl SampleStore {
    /// Unbounded store (the paper's protocol).
    pub fn new(d: usize) -> SampleStore {
        SampleStore { x: Vec::new(), y: Vec::new(), d, capacity: None, ingested: 0 }
    }

    /// Capacity-bound store with reservoir-sampling eviction (the
    /// online-learning extension): after `capacity` samples the store
    /// holds a uniform random subset of everything ingested.
    pub fn with_capacity(d: usize, capacity: usize) -> SampleStore {
        assert!(capacity > 0, "capacity must be positive");
        SampleStore {
            x: Vec::with_capacity(capacity * d),
            y: Vec::with_capacity(capacity),
            d,
            capacity: Some(capacity),
            ingested: 0,
        }
    }

    /// Re-arm the store for a new run: drop all samples and adopt the
    /// run's dimension/capacity, keeping the backing buffers so a
    /// workspace-reused run performs no store allocation after warm-up.
    pub fn reset(&mut self, d: usize, capacity: Option<usize>) {
        if let Some(cap) = capacity {
            assert!(cap > 0, "capacity must be positive");
        }
        self.x.clear();
        self.y.clear();
        self.d = d;
        self.capacity = capacity;
        self.ingested = 0;
        if let Some(cap) = capacity {
            self.x.reserve(cap * d);
            self.y.reserve(cap);
        }
    }

    /// Number of samples currently held.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Total samples ever ingested.
    pub fn ingested(&self) -> usize {
        self.ingested
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Ingest one block of samples (row-major `x`, labels `y`).
    ///
    /// `rng` drives reservoir eviction and is only consulted when a
    /// capacity is set (keeps unbounded runs bit-identical regardless of
    /// the extension).
    pub fn ingest(&mut self, x: &[f32], y: &[f32], rng: &mut Pcg32) {
        assert_eq!(x.len(), y.len() * self.d, "block shape mismatch");
        match self.capacity {
            None => {
                self.x.extend_from_slice(x);
                self.y.extend_from_slice(y);
                self.ingested += y.len();
            }
            Some(cap) => {
                for (i, &label) in y.iter().enumerate() {
                    let row = &x[i * self.d..(i + 1) * self.d];
                    self.ingested += 1;
                    if self.y.len() < cap {
                        self.x.extend_from_slice(row);
                        self.y.push(label);
                    } else {
                        // classic reservoir: replace slot j < cap with
                        // probability cap/ingested
                        let j = rng.gen_range(self.ingested as u64) as usize;
                        if j < cap {
                            self.x[j * self.d..(j + 1) * self.d]
                                .copy_from_slice(row);
                            self.y[j] = label;
                        }
                    }
                }
            }
        }
    }

    /// Borrow the store contents as an SGD view.
    pub fn view(&self) -> StoreView<'_> {
        StoreView::new(&self.x, &self.y, self.d)
    }

    /// Row `i` (for loss computations).
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Label of row `i`.
    pub fn label(&self, i: usize) -> f32 {
        self.y[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(vals: &[f32]) -> (Vec<f32>, Vec<f32>) {
        // 2-d rows [v, v+1], label v
        let mut x = Vec::new();
        let mut y = Vec::new();
        for &v in vals {
            x.extend_from_slice(&[v, v + 1.0]);
            y.push(v);
        }
        (x, y)
    }

    #[test]
    fn unbounded_growth_preserves_order() {
        let mut store = SampleStore::new(2);
        let mut rng = Pcg32::seeded(1);
        let (x1, y1) = block(&[1.0, 2.0]);
        let (x2, y2) = block(&[3.0]);
        store.ingest(&x1, &y1, &mut rng);
        store.ingest(&x2, &y2, &mut rng);
        assert_eq!(store.len(), 3);
        assert_eq!(store.ingested(), 3);
        assert_eq!(store.row(2), &[3.0, 4.0]);
        assert_eq!(store.label(0), 1.0);
    }

    #[test]
    fn capacity_bound_holds() {
        let mut store = SampleStore::with_capacity(2, 5);
        let mut rng = Pcg32::seeded(2);
        for chunk in 0..20 {
            let (x, y) = block(&[chunk as f32, chunk as f32 + 0.5]);
            store.ingest(&x, &y, &mut rng);
        }
        assert_eq!(store.len(), 5);
        assert_eq!(store.ingested(), 40);
    }

    #[test]
    fn reservoir_is_unbiased() {
        // Each of 100 streamed samples should survive with p = cap/100.
        let cap = 10;
        let trials = 4000;
        let mut counts = vec![0u32; 100];
        for t in 0..trials {
            let mut store = SampleStore::with_capacity(1, cap);
            let mut rng = Pcg32::seeded(100 + t as u64);
            for v in 0..100 {
                store.ingest(&[v as f32], &[v as f32], &mut rng);
            }
            for i in 0..store.len() {
                counts[store.label(i) as usize] += 1;
            }
        }
        let expect = trials as f64 * cap as f64 / 100.0;
        for (v, &c) in counts.iter().enumerate() {
            let rel = (c as f64 - expect) / expect;
            assert!(rel.abs() < 0.2, "sample {v}: count {c} vs {expect}");
        }
    }

    #[test]
    fn view_matches_contents() {
        let mut store = SampleStore::new(2);
        let mut rng = Pcg32::seeded(3);
        let (x, y) = block(&[7.0]);
        store.ingest(&x, &y, &mut rng);
        let view = store.view();
        assert_eq!(view.len(), 1);
        assert_eq!(view.row(0), &[7.0, 8.0]);
    }
}
