//! The PJRT session: one CPU client plus a cache of compiled executables.
//!
//! Compilation happens once per artifact per process (it dominates
//! startup, ~100 ms–1 s each); execution afterwards is pure C++ with no
//! Python anywhere.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::manifest::Manifest;

/// A live PJRT CPU client with compiled artifacts.
pub struct RuntimeSession {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl RuntimeSession {
    /// Create a session over an artifact directory (compiles lazily; call
    /// [`preload`](Self::preload) to compile up front).
    pub fn open(artifact_dir: &Path) -> Result<RuntimeSession> {
        let manifest = Manifest::load(artifact_dir)?;
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeSession {
            client,
            manifest,
            executables: BTreeMap::new(),
        })
    }

    /// Open using [`find_artifact_dir`](super::find_artifact_dir).
    pub fn open_default() -> Result<RuntimeSession> {
        let dir = super::find_artifact_dir().context(
            "artifacts not found — run `make artifacts` (or set \
             EDGEPIPE_ARTIFACTS)",
        )?;
        Self::open(&dir)
    }

    /// Compile (and cache) one artifact by name.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let path = self.manifest.path_of(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {name} HLO: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Compile a set of artifacts up front.
    pub fn preload(&mut self, names: &[&str]) -> Result<()> {
        for name in names {
            self.load(name)?;
        }
        Ok(())
    }

    /// Execute a loaded artifact on literal inputs; returns the flattened
    /// output tuple (aot.py lowers everything with `return_tuple=True`).
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e}"))?;
        literal
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name} result: {e}"))
    }
}

/// Build an `f32` literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(
        expect as usize == data.len(),
        "literal shape {:?} != data len {}",
        dims,
        data.len()
    );
    let flat = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(flat);
    }
    flat.reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape to {dims:?}: {e}"))
}

/// Read an `f32` literal back into a Vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("reading literal: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::find_artifact_dir;

    #[test]
    fn session_compiles_and_runs_sgd_block() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut sess = RuntimeSession::open(&dir).unwrap();
        let c = sess.manifest.constants;
        // zero alpha -> w must pass through unchanged
        let w: Vec<f32> = (0..c.d).map(|i| i as f32 * 0.5).collect();
        let inputs = vec![
            literal_f32(&w, &[1, c.d as i64]).unwrap(),
            literal_f32(&vec![0.0; c.k_max * c.d], &[c.k_max as i64, c.d as i64])
                .unwrap(),
            literal_f32(&vec![0.0; c.k_max], &[c.k_max as i64]).unwrap(),
            literal_f32(&vec![1.0; c.k_max], &[c.k_max as i64]).unwrap(),
            literal_f32(&[0.0, 0.0], &[1, 2]).unwrap(),
        ];
        let out = sess.execute("sgd_block", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        let got = to_vec_f32(&out[0]).unwrap();
        assert_eq!(got, w);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
    }
}
