//! The PJRT session: one CPU client plus a cache of compiled executables.
//!
//! Compilation happens once per artifact per process (it dominates
//! startup, ~100 ms–1 s each); execution afterwards is pure C++ with no
//! Python anywhere.
//!
//! The real client needs the `xla` crate and a `libxla_extension`
//! install, which the offline build image does not carry — so the whole
//! session is gated behind the `pjrt` cargo feature. Without it, an
//! API-identical stub compiles in whose constructors fail with a clear
//! message, keeping every caller (executor, loss, mlp, CLI `--backend
//! pjrt`) compiling and the native backend fully functional.

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::BTreeMap;
    use std::path::Path;

    use anyhow::{Context, Result};

    use crate::runtime::manifest::Manifest;

    /// An XLA literal (re-exported so callers never name `xla::`).
    pub use xla::Literal;

    /// A live PJRT CPU client with compiled artifacts.
    pub struct RuntimeSession {
        pub client: xla::PjRtClient,
        pub manifest: Manifest,
        executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    }

    impl RuntimeSession {
        /// Create a session over an artifact directory (compiles lazily;
        /// call [`preload`](Self::preload) to compile up front).
        pub fn open(artifact_dir: &Path) -> Result<RuntimeSession> {
            let manifest = Manifest::load(artifact_dir)?;
            let client =
                xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(RuntimeSession {
                client,
                manifest,
                executables: BTreeMap::new(),
            })
        }

        /// Open using [`find_artifact_dir`](crate::runtime::find_artifact_dir).
        pub fn open_default() -> Result<RuntimeSession> {
            let dir = crate::runtime::find_artifact_dir().context(
                "artifacts not found — run `make artifacts` (or set \
                 EDGEPIPE_ARTIFACTS)",
            )?;
            Self::open(&dir)
        }

        /// Compile (and cache) one artifact by name.
        pub fn load(
            &mut self,
            name: &str,
        ) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.executables.contains_key(name) {
                let path = self.manifest.path_of(name)?;
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| {
                        anyhow::anyhow!("parsing {name} HLO: {e}")
                    })?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
                self.executables.insert(name.to_string(), exe);
            }
            Ok(&self.executables[name])
        }

        /// Compile a set of artifacts up front.
        pub fn preload(&mut self, names: &[&str]) -> Result<()> {
            for name in names {
                self.load(name)?;
            }
            Ok(())
        }

        /// Execute a loaded artifact on literal inputs; returns the
        /// flattened output tuple (aot.py lowers everything with
        /// `return_tuple=True`).
        pub fn execute(
            &mut self,
            name: &str,
            inputs: &[xla::Literal],
        ) -> Result<Vec<xla::Literal>> {
            let exe = self.load(name)?;
            let result = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
            let literal = result[0][0].to_literal_sync().map_err(|e| {
                anyhow::anyhow!("fetching {name} result: {e}")
            })?;
            literal
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("untupling {name} result: {e}"))
        }
    }

    /// Build an `f32` literal of the given shape from a flat slice.
    pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        let expect: i64 = dims.iter().product();
        anyhow::ensure!(
            expect as usize == data.len(),
            "literal shape {:?} != data len {}",
            dims,
            data.len()
        );
        let flat = xla::Literal::vec1(data);
        if dims.len() == 1 {
            return Ok(flat);
        }
        flat.reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape to {dims:?}: {e}"))
    }

    /// Read an `f32` literal back into a Vec.
    pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("reading literal: {e}"))
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use anyhow::{bail, Context, Result};

    use crate::runtime::manifest::Manifest;

    const DISABLED: &str = "edgepipe was built without the `pjrt` \
        feature; rebuild with `cargo build --features pjrt` (needs the \
        `xla` crate and libxla_extension) to run AOT artifacts";

    /// Opaque stand-in for `xla::Literal`; carries no data and is only
    /// produced by [`literal_f32`] so callers type-check unchanged.
    pub struct Literal;

    /// Stub session: constructors always fail with a clear message.
    pub struct RuntimeSession {
        pub manifest: Manifest,
    }

    impl RuntimeSession {
        pub fn open(artifact_dir: &Path) -> Result<RuntimeSession> {
            // Validate the manifest anyway so configuration errors
            // surface before the feature message.
            let _ = Manifest::load(artifact_dir)?;
            bail!("{DISABLED}")
        }

        pub fn open_default() -> Result<RuntimeSession> {
            let dir = crate::runtime::find_artifact_dir().context(
                "artifacts not found — run `make artifacts` (or set \
                 EDGEPIPE_ARTIFACTS)",
            )?;
            Self::open(&dir)
        }

        pub fn load(&mut self, _name: &str) -> Result<&Literal> {
            bail!("{DISABLED}")
        }

        pub fn preload(&mut self, _names: &[&str]) -> Result<()> {
            bail!("{DISABLED}")
        }

        pub fn execute(
            &mut self,
            _name: &str,
            _inputs: &[Literal],
        ) -> Result<Vec<Literal>> {
            bail!("{DISABLED}")
        }
    }

    /// Shape-checks like the real helper, then returns an opaque token.
    pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        let expect: i64 = dims.iter().product();
        anyhow::ensure!(
            expect as usize == data.len(),
            "literal shape {:?} != data len {}",
            dims,
            data.len()
        );
        Ok(Literal)
    }

    pub fn to_vec_f32(_lit: &Literal) -> Result<Vec<f32>> {
        bail!("{DISABLED}")
    }
}

pub use imp::{literal_f32, to_vec_f32, Literal, RuntimeSession};

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn session_compiles_and_runs_sgd_block() {
        use crate::runtime::find_artifact_dir;
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut sess = RuntimeSession::open(&dir).unwrap();
        let c = sess.manifest.constants;
        // zero alpha -> w must pass through unchanged
        let w: Vec<f32> = (0..c.d).map(|i| i as f32 * 0.5).collect();
        let inputs = vec![
            literal_f32(&w, &[1, c.d as i64]).unwrap(),
            literal_f32(
                &vec![0.0; c.k_max * c.d],
                &[c.k_max as i64, c.d as i64],
            )
            .unwrap(),
            literal_f32(&vec![0.0; c.k_max], &[c.k_max as i64]).unwrap(),
            literal_f32(&vec![1.0; c.k_max], &[c.k_max as i64]).unwrap(),
            literal_f32(&[0.0, 0.0], &[1, 2]).unwrap(),
        ];
        let out = sess.execute("sgd_block", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        let got = to_vec_f32(&out[0]).unwrap();
        assert_eq!(got, w);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_session_reports_disabled_feature() {
        let dir = std::env::temp_dir().join("edgepipe_no_such_artifacts");
        let err = RuntimeSession::open(&dir).unwrap_err();
        // manifest load fails first for a missing dir — the message must
        // point at one of the two real causes
        let text = format!("{err:#}");
        assert!(
            text.contains("manifest") || text.contains("pjrt"),
            "unhelpful error: {text}"
        );
    }
}
