//! Build-artifact manifest support for the JAX/Pallas build-time layer.
//!
//! `make artifacts` (build time, Python) lowers each Layer-2 entry point
//! to HLO **text** plus a `manifest.json` describing shapes; this module
//! parses and locates those artifacts so Rust-side tooling can validate
//! what the build produced. Python never runs here, and nothing on the
//! request path depends on the artifacts — the crate's only executors
//! are the native engine and the batched-seed sweep engine
//! (`sweep/batch.rs`).
//!
//! * [`manifest`] — parse + validate `artifacts/manifest.json`

pub mod manifest;

pub use manifest::{ArtifactMeta, Manifest, TensorMeta};

/// Default artifact directory, relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$EDGEPIPE_ARTIFACTS`, else
/// `artifacts/` relative to the current dir, else relative to the crate
/// root (so tests work from any cwd). Returns None when missing.
pub fn find_artifact_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("EDGEPIPE_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let cwd = std::path::PathBuf::from(DEFAULT_ARTIFACT_DIR);
    if cwd.join("manifest.json").exists() {
        return Some(cwd);
    }
    let crate_rel = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join(DEFAULT_ARTIFACT_DIR);
    if crate_rel.join("manifest.json").exists() {
        return Some(crate_rel);
    }
    None
}
