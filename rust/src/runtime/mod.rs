//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` (build time, Python) lowers each Layer-2 entry point
//! to HLO **text** plus a `manifest.json` describing shapes; this module
//! is the request-path half: it compiles the text on the PJRT CPU client
//! once and executes it from the coordinator's hot loop. Python never
//! runs here.
//!
//! * [`manifest`] — parse + validate `artifacts/manifest.json`
//! * [`session`]  — PJRT client + compiled-executable cache
//! * [`executor`] — [`PjrtExecutor`], the `BlockExecutor` backend running
//!   the `sgd_block` Pallas kernel
//! * [`loss`]     — full-dataset loss/gradient evaluation via artifacts
//! * [`mlp`]      — the MLP training step used by the extension example

pub mod executor;
pub mod loss;
pub mod manifest;
pub mod mlp;
pub mod session;

pub use executor::PjrtExecutor;
pub use loss::PjrtLossEvaluator;
pub use manifest::{ArtifactMeta, Manifest, TensorMeta};
pub use session::RuntimeSession;

/// Default artifact directory, relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$EDGEPIPE_ARTIFACTS`, else
/// `artifacts/` relative to the current dir, else relative to the crate
/// root (so tests work from any cwd). Returns None when missing.
pub fn find_artifact_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("EDGEPIPE_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let cwd = std::path::PathBuf::from(DEFAULT_ARTIFACT_DIR);
    if cwd.join("manifest.json").exists() {
        return Some(cwd);
    }
    let crate_rel = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join(DEFAULT_ARTIFACT_DIR);
    if crate_rel.join("manifest.json").exists() {
        return Some(crate_rel);
    }
    None
}
