//! `artifacts/manifest.json` parsing and shape validation.
//!
//! The manifest is written by `python -m compile.aot` and is the contract
//! between build-time Python and the Rust runtime: artifact file names,
//! exact input/output shapes and dtypes, and the fixed capacity constants
//! (K_MAX step slots, N_CAP row buffer, tile sizes).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::parse;

/// One tensor's shape/dtype as recorded by aot.py.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact (compiled entry point).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub sha256: String,
}

/// Capacity constants shared with `python/compile/shapes.py`.
#[derive(Clone, Copy, Debug)]
pub struct Constants {
    pub d: usize,
    pub k_max: usize,
    pub n_raw: usize,
    pub n_cap: usize,
    pub loss_tile: usize,
    pub mlp_hidden: usize,
    pub mlp_batch: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub constants: Constants,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = parse(&text).context("parsing manifest.json")?;
        let format = root.get("format")?.as_usize()?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let c = root.get("constants")?;
        let constants = Constants {
            d: c.get("d")?.as_usize()?,
            k_max: c.get("k_max")?.as_usize()?,
            n_raw: c.get("n_raw")?.as_usize()?,
            n_cap: c.get("n_cap")?.as_usize()?,
            loss_tile: c.get("loss_tile")?.as_usize()?,
            mlp_hidden: c.get("mlp_hidden")?.as_usize()?,
            mlp_batch: c.get("mlp_batch")?.as_usize()?,
        };
        let mut artifacts = BTreeMap::new();
        for (name, meta) in root.get("artifacts")?.as_obj()? {
            let parse_tensors = |key: &str| -> Result<Vec<TensorMeta>> {
                meta.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|t| {
                        Ok(TensorMeta {
                            name: t
                                .opt("name")
                                .map(|v| v.as_str().map(str::to_string))
                                .transpose()?
                                .unwrap_or_default(),
                            shape: t
                                .get("shape")?
                                .as_arr()?
                                .iter()
                                .map(|v| v.as_usize())
                                .collect::<Result<_>>()?,
                            dtype: t.get("dtype")?.as_str()?.to_string(),
                        })
                    })
                    .collect()
            };
            let art = ArtifactMeta {
                name: name.clone(),
                file: meta.get("file")?.as_str()?.to_string(),
                inputs: parse_tensors("inputs")?,
                outputs: parse_tensors("outputs")?,
                sha256: meta.get("sha256")?.as_str()?.to_string(),
            };
            let file = dir.join(&art.file);
            if !file.exists() {
                bail!("artifact file missing: {}", file.display());
            }
            artifacts.insert(name.clone(), art);
        }
        let m = Manifest { dir: dir.to_path_buf(), constants, artifacts };
        m.validate()?;
        Ok(m)
    }

    /// Cross-check the invariants the runtime depends on.
    pub fn validate(&self) -> Result<()> {
        let c = &self.constants;
        if c.n_cap % c.loss_tile != 0 {
            bail!("n_cap {} not a multiple of loss tile {}", c.n_cap, c.loss_tile);
        }
        if c.n_cap < c.n_raw {
            bail!("n_cap {} < n_raw {}", c.n_cap, c.n_raw);
        }
        if let Some(sgd) = self.artifacts.get("sgd_block") {
            let want = [
                vec![1, c.d],
                vec![c.k_max, c.d],
                vec![c.k_max],
                vec![c.k_max],
                vec![1, 2],
            ];
            for (tensor, want) in sgd.inputs.iter().zip(&want) {
                if &tensor.shape != want {
                    bail!(
                        "sgd_block input '{}' shape {:?}, want {:?}",
                        tensor.name,
                        tensor.shape,
                        want
                    );
                }
                if tensor.dtype != "float32" {
                    bail!("sgd_block expects float32 inputs");
                }
            }
        }
        Ok(())
    }

    /// Fetch an artifact or fail with its name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Absolute path of an artifact's HLO text.
    pub fn path_of(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::find_artifact_dir;

    #[test]
    fn loads_real_manifest_when_built() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.constants.d, 8);
        assert_eq!(m.constants.k_max, 512);
        assert!(m.artifacts.contains_key("sgd_block"));
        assert!(m.artifacts.contains_key("dataset_loss"));
        let sgd = m.artifact("sgd_block").unwrap();
        assert_eq!(sgd.inputs.len(), 5);
        assert_eq!(sgd.outputs[0].shape, vec![1, 8]);
    }

    #[test]
    fn missing_artifact_is_error() {
        let Some(dir) = find_artifact_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifact("nonexistent").is_err());
        assert!(m.path_of("sgd_block").unwrap().exists());
    }
}
