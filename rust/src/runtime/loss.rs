//! Full-dataset loss/gradient evaluation through the `dataset_loss` /
//! `dataset_grad` / `batch_step` artifacts (masked fixed-capacity row
//! buffer; one artifact serves every store size), plus the native
//! kernel-backed evaluation of the same buffer
//! ([`PjrtLossEvaluator::loss_native`] /
//! [`grad_native`](PjrtLossEvaluator::grad_native)) used to cross-check
//! artifacts and as the offline reference.

use anyhow::{ensure, Result};

use crate::linalg::kernels::{axpy_f32_f64, batch_ridge_loss, dot_f32_f64};

use super::session::{literal_f32, to_vec_f32, RuntimeSession};

/// Evaluates the empirical ridge loss / gradient over a fixed-capacity
/// padded buffer via PJRT.
pub struct PjrtLossEvaluator {
    session: RuntimeSession,
    /// Padded row buffer (N_CAP × d), row-major.
    xx: Vec<f32>,
    /// Padded labels (N_CAP).
    yy: Vec<f32>,
    /// Validity mask (N_CAP).
    mask: Vec<f32>,
    /// Valid row count.
    count: usize,
    n_cap: usize,
    d: usize,
    /// λ/N.
    reg: f32,
    /// 2λ/N.
    reg2: f32,
}

impl PjrtLossEvaluator {
    /// Build over a session for a dataset with `n_full` samples total
    /// (fixes the λ/N regularizer scale).
    pub fn new(
        mut session: RuntimeSession,
        lambda: f64,
        n_full: usize,
    ) -> Result<PjrtLossEvaluator> {
        session.preload(&["dataset_loss"])?;
        let c = session.manifest.constants;
        ensure!(
            n_full <= c.n_cap,
            "dataset of {n_full} exceeds artifact capacity {}",
            c.n_cap
        );
        Ok(PjrtLossEvaluator {
            xx: vec![0.0; c.n_cap * c.d],
            yy: vec![0.0; c.n_cap],
            mask: vec![0.0; c.n_cap],
            count: 0,
            n_cap: c.n_cap,
            d: c.d,
            reg: (lambda / n_full as f64) as f32,
            reg2: (2.0 * lambda / n_full as f64) as f32,
            session,
        })
    }

    /// Number of valid rows currently loaded.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Append rows to the buffer (mirrors the edge store growing).
    pub fn append_rows(&mut self, x: &[f32], y: &[f32]) -> Result<()> {
        ensure!(x.len() == y.len() * self.d, "row shape mismatch");
        ensure!(
            self.count + y.len() <= self.n_cap,
            "buffer overflow: {} + {} > {}",
            self.count,
            y.len(),
            self.n_cap
        );
        let start = self.count;
        self.xx[start * self.d..(start + y.len()) * self.d]
            .copy_from_slice(x);
        self.yy[start..start + y.len()].copy_from_slice(y);
        for m in &mut self.mask[start..start + y.len()] {
            *m = 1.0;
        }
        self.count += y.len();
        Ok(())
    }

    /// Reset to an empty buffer.
    pub fn clear(&mut self) {
        self.xx.fill(0.0);
        self.yy.fill(0.0);
        self.mask.fill(0.0);
        self.count = 0;
    }

    /// Empirical ridge loss over the loaded rows at parameters `w`.
    pub fn loss(&mut self, w: &[f64]) -> Result<f64> {
        ensure!(self.count > 0, "loss over an empty buffer");
        let w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
        let inputs = [
            literal_f32(&w32, &[1, self.d as i64])?,
            literal_f32(&self.xx, &[self.n_cap as i64, self.d as i64])?,
            literal_f32(&self.yy, &[self.n_cap as i64])?,
            literal_f32(&self.mask, &[self.n_cap as i64])?,
            literal_f32(&[self.count as f32, self.reg], &[1, 2])?,
        ];
        let out = self.session.execute("dataset_loss", &inputs)?;
        Ok(to_vec_f32(&out[0])?[0] as f64)
    }

    /// Native (f64, batched-kernel) evaluation of the loaded rows —
    /// the semantics `dataset_loss` computes in f32 on-device. Used to
    /// cross-check artifacts and as the offline reference path.
    /// Panics on an empty buffer (where [`loss`](Self::loss) errors).
    pub fn loss_native(&self, w: &[f64]) -> f64 {
        assert!(self.count > 0, "loss over an empty buffer");
        batch_ridge_loss(
            &self.xx[..self.count * self.d],
            &self.yy[..self.count],
            self.d,
            w,
            self.reg as f64,
        )
    }

    /// Native (f64, kernel) mean ridge gradient over the loaded rows —
    /// the semantics `dataset_grad` computes in f32 on-device.
    /// Panics on an empty buffer (where [`grad`](Self::grad) errors).
    pub fn grad_native(&self, w: &[f64]) -> Vec<f64> {
        assert!(self.count > 0, "grad over an empty buffer");
        let mut g = vec![0.0f64; self.d];
        for (i, &yi) in self.yy[..self.count].iter().enumerate() {
            let row = &self.xx[i * self.d..(i + 1) * self.d];
            let e2 = 2.0 * (dot_f32_f64(w, row) - yi as f64);
            axpy_f32_f64(e2, row, &mut g);
        }
        let n = self.count as f64;
        for (gj, &wj) in g.iter_mut().zip(w) {
            *gj = *gj / n + self.reg2 as f64 * wj;
        }
        g
    }

    /// Empirical ridge gradient over the loaded rows at `w`.
    pub fn grad(&mut self, w: &[f64]) -> Result<Vec<f64>> {
        ensure!(self.count > 0, "grad over an empty buffer");
        let w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
        let inputs = [
            literal_f32(&w32, &[1, self.d as i64])?,
            literal_f32(&self.xx, &[self.n_cap as i64, self.d as i64])?,
            literal_f32(&self.yy, &[self.n_cap as i64])?,
            literal_f32(&self.mask, &[self.n_cap as i64])?,
            literal_f32(&[self.count as f32, self.reg2], &[1, 2])?,
        ];
        let out = self.session.execute("dataset_grad", &inputs)?;
        Ok(to_vec_f32(&out[0])?.iter().map(|&v| v as f64).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::runtime::find_artifact_dir;
    use crate::runtime::session::RuntimeSession;

    #[test]
    fn loss_matches_native_f64() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ds = synth_calhousing(&SynthSpec { n: 3000, ..Default::default() });
        let lambda = 0.05;
        let session = RuntimeSession::open(&dir).unwrap();
        let mut eval = PjrtLossEvaluator::new(session, lambda, ds.n).unwrap();
        eval.append_rows(&ds.x, &ds.y).unwrap();
        assert_eq!(eval.count(), ds.n);

        let w = vec![0.5, -0.25, 0.1, 0.7, -0.3, 0.2, 0.05, -0.6];
        let got = eval.loss(&w).unwrap();
        let want = ds.ridge_loss(&w, lambda / ds.n as f64);
        let rel = (got - want).abs() / want;
        assert!(rel < 1e-4, "pjrt {got} vs native {want}");
        // the kernel-backed buffer evaluation is the same number
        let native = eval.loss_native(&w);
        assert!(
            (native - want).abs() / want < 1e-6,
            "buffer-native {native} vs dataset {want}"
        );
    }

    #[test]
    fn grad_matches_native_f64() {
        let Some(dir) = find_artifact_dir() else {
            return;
        };
        let ds = synth_calhousing(&SynthSpec { n: 2000, ..Default::default() });
        let lambda = 0.05;
        let session = RuntimeSession::open(&dir).unwrap();
        let mut eval = PjrtLossEvaluator::new(session, lambda, ds.n).unwrap();
        eval.append_rows(&ds.x, &ds.y).unwrap();
        let w = vec![0.3, -0.1, 0.2, 0.4, -0.5, 0.6, -0.7, 0.05];
        let got = eval.grad(&w).unwrap();
        // kernel-backed native reference over the same buffer
        let want = eval.grad_native(&w);
        // ...which must itself agree with the per-row model gradient
        use crate::model::{PointModel, RidgeModel};
        let model = RidgeModel::new(ds.d, lambda, ds.n);
        let mut by_rows = vec![0.0; ds.d];
        let mut g = vec![0.0; ds.d];
        for i in 0..ds.n {
            model.grad_into(&w, ds.row(i), ds.y[i], &mut g);
            for j in 0..ds.d {
                by_rows[j] += g[j];
            }
        }
        for (j, v) in by_rows.iter_mut().enumerate() {
            *v /= ds.n as f64;
            assert!(
                (*v - want[j]).abs() < 1e-9,
                "kernel grad vs per-row grad at {j}: {} vs {v}",
                want[j]
            );
        }
        for j in 0..ds.d {
            assert!(
                (got[j] - want[j]).abs() < 1e-3,
                "coord {j}: {} vs {}",
                got[j],
                want[j]
            );
        }
    }

    #[test]
    fn growing_buffer_matches_subset_loss() {
        let Some(dir) = find_artifact_dir() else {
            return;
        };
        let ds = synth_calhousing(&SynthSpec { n: 1000, ..Default::default() });
        let session = RuntimeSession::open(&dir).unwrap();
        let mut eval = PjrtLossEvaluator::new(session, 0.0, ds.n).unwrap();
        // load only the first 300 rows
        eval.append_rows(&ds.x[..300 * ds.d], &ds.y[..300]).unwrap();
        let w = vec![0.1; 8];
        let got = eval.loss(&w).unwrap();
        let sub = ds.subset(&(0..300).collect::<Vec<_>>());
        let want = sub.ridge_loss(&w, 0.0);
        assert!((got - want).abs() / want < 1e-4, "{got} vs {want}");
    }
}
