//! MLP training through the `mlp_step` / `mlp_loss` artifacts — the
//! model-generality extension: the same pipelined protocol driving a
//! nonlinear model whose forward/backward runs entirely in the AOT
//! JAX/Pallas artifact (fused tiled matmul kernels).

use anyhow::{ensure, Result};

use crate::util::rng::Pcg32;

use super::session::{literal_f32, to_vec_f32, Literal, RuntimeSession};

/// Host-side MLP parameter set (shapes fixed by the manifest).
#[derive(Clone, Debug)]
pub struct MlpParams {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub w3: Vec<f32>,
    pub b3: Vec<f32>,
    pub d_in: usize,
    pub hidden: usize,
}

impl MlpParams {
    /// He-style random init.
    pub fn init(d_in: usize, hidden: usize, rng: &mut Pcg32) -> MlpParams {
        let g = |n: usize, scale: f64, rng: &mut Pcg32| -> Vec<f32> {
            (0..n).map(|_| (rng.next_gaussian() * scale) as f32).collect()
        };
        let s1 = (2.0 / d_in as f64).sqrt();
        let s2 = (2.0 / hidden as f64).sqrt();
        MlpParams {
            w1: g(d_in * hidden, s1, rng),
            b1: vec![0.0; hidden],
            w2: g(hidden * hidden, s2, rng),
            b2: vec![0.0; hidden],
            w3: g(hidden, s2, rng),
            b3: vec![0.0; 1],
            d_in,
            hidden,
        }
    }

    /// Total parameter count.
    pub fn count(&self) -> usize {
        self.w1.len()
            + self.b1.len()
            + self.w2.len()
            + self.b2.len()
            + self.w3.len()
            + self.b3.len()
    }
}

/// PJRT-backed MLP trainer.
pub struct PjrtMlp {
    session: RuntimeSession,
    pub batch: usize,
    pub d_in: usize,
    pub hidden: usize,
}

impl PjrtMlp {
    pub fn new(mut session: RuntimeSession) -> Result<PjrtMlp> {
        session.preload(&["mlp_step", "mlp_loss"])?;
        let c = session.manifest.constants;
        Ok(PjrtMlp {
            batch: c.mlp_batch,
            d_in: c.d,
            hidden: c.mlp_hidden,
            session,
        })
    }

    fn param_literals(&self, p: &MlpParams) -> Result<Vec<Literal>> {
        let (d, h) = (self.d_in as i64, self.hidden as i64);
        Ok(vec![
            literal_f32(&p.w1, &[d, h])?,
            literal_f32(&p.b1, &[1, h])?,
            literal_f32(&p.w2, &[h, h])?,
            literal_f32(&p.b2, &[1, h])?,
            literal_f32(&p.w3, &[h, 1])?,
            literal_f32(&p.b3, &[1, 1])?,
        ])
    }

    /// One SGD step on a batch; updates `p` in place and returns the
    /// pre-step batch loss (as computed inside the artifact).
    pub fn step(
        &mut self,
        p: &mut MlpParams,
        x: &[f32],
        y: &[f32],
        alpha: f32,
    ) -> Result<f64> {
        ensure!(y.len() == self.batch, "batch must be exactly {}", self.batch);
        ensure!(x.len() == self.batch * self.d_in, "x shape mismatch");
        let mut inputs = vec![
            literal_f32(x, &[self.batch as i64, self.d_in as i64])?,
            literal_f32(y, &[self.batch as i64])?,
        ];
        inputs.extend(self.param_literals(p)?);
        inputs.push(literal_f32(&[alpha], &[1, 1])?);
        let out = self.session.execute("mlp_step", &inputs)?;
        ensure!(out.len() == 7, "mlp_step returns 7 outputs");
        p.w1 = to_vec_f32(&out[0])?;
        p.b1 = to_vec_f32(&out[1])?;
        p.w2 = to_vec_f32(&out[2])?;
        p.b2 = to_vec_f32(&out[3])?;
        p.w3 = to_vec_f32(&out[4])?;
        p.b3 = to_vec_f32(&out[5])?;
        Ok(to_vec_f32(&out[6])?[0] as f64)
    }

    /// Batch MSE loss at the current parameters.
    pub fn loss(
        &mut self,
        p: &MlpParams,
        x: &[f32],
        y: &[f32],
    ) -> Result<f64> {
        let mut inputs = vec![
            literal_f32(x, &[self.batch as i64, self.d_in as i64])?,
            literal_f32(y, &[self.batch as i64])?,
        ];
        inputs.extend(self.param_literals(p)?);
        let out = self.session.execute("mlp_loss", &inputs)?;
        Ok(to_vec_f32(&out[0])?[0] as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::find_artifact_dir;

    #[test]
    fn mlp_training_reduces_loss_via_pjrt() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let session = RuntimeSession::open(&dir).unwrap();
        let mut mlp = PjrtMlp::new(session).unwrap();
        let mut rng = Pcg32::seeded(5);
        let mut p = MlpParams::init(mlp.d_in, mlp.hidden, &mut rng);
        assert!(p.count() > 60_000, "param count {}", p.count());

        // fixed synthetic batch: y = tanh(x . w) target
        let n = mlp.batch;
        let x: Vec<f32> = (0..n * mlp.d_in)
            .map(|_| rng.next_gaussian() as f32)
            .collect();
        let wt: Vec<f64> = (0..mlp.d_in).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f32> = (0..n)
            .map(|i| {
                let dot: f64 = (0..mlp.d_in)
                    .map(|j| x[i * mlp.d_in + j] as f64 * wt[j])
                    .sum();
                dot.tanh() as f32
            })
            .collect();

        let l0 = mlp.loss(&p, &x, &y).unwrap();
        for _ in 0..30 {
            mlp.step(&mut p, &x, &y, 0.05).unwrap();
        }
        let l1 = mlp.loss(&p, &x, &y).unwrap();
        assert!(l1 < 0.5 * l0, "loss {l0} -> {l1}");
    }
}
