//! [`PjrtExecutor`]: the `BlockExecutor` backend that runs the paper's
//! hot path — one pipelined block of SGD updates — through the AOT
//! JAX/Pallas `sgd_block` artifact.
//!
//! The coordinator samples the indices; this executor gathers the sampled
//! rows into the kernel's fixed `(K_MAX, d)` tile (the HBM→VMEM-friendly
//! layout from DESIGN.md §Hardware-Adaptation), masks unused step slots,
//! and loops calls when a block carries more than K_MAX updates.
//! Parameters cross the f64 (coordinator) / f32 (artifact) boundary once
//! per call, not per update.

use anyhow::Result;

use crate::coordinator::BlockExecutor;
use crate::sgd::StoreView;

use super::session::{literal_f32, to_vec_f32, RuntimeSession};

/// PJRT-backed block executor for the ridge workload.
pub struct PjrtExecutor {
    session: RuntimeSession,
    /// α (learning rate).
    alpha: f32,
    /// 2λ/N (gradient regularizer coefficient).
    reg2: f32,
    k_max: usize,
    d: usize,
    // reusable staging buffers (avoid per-call allocation)
    xs: Vec<f32>,
    ys: Vec<f32>,
    mask: Vec<f32>,
    calls: u64,
}

impl PjrtExecutor {
    /// Build over a session, pre-compiling the `sgd_block` artifact.
    /// `lambda` and `n_full` fix the regularizer exactly as the native
    /// `RidgeModel` does.
    pub fn new(
        mut session: RuntimeSession,
        alpha: f64,
        lambda: f64,
        n_full: usize,
    ) -> Result<PjrtExecutor> {
        session.preload(&["sgd_block"])?;
        let c = session.manifest.constants;
        Ok(PjrtExecutor {
            alpha: alpha as f32,
            reg2: (2.0 * lambda / n_full as f64) as f32,
            k_max: c.k_max,
            d: c.d,
            xs: vec![0.0; c.k_max * c.d],
            ys: vec![0.0; c.k_max],
            mask: vec![0.0; c.k_max],
            session,
            calls: 0,
        })
    }

    /// Number of artifact invocations so far (for perf accounting).
    pub fn calls(&self) -> u64 {
        self.calls
    }

    /// Run one chunk of at most K_MAX updates.
    fn run_chunk(
        &mut self,
        w: &mut [f32],
        store: StoreView<'_>,
        indices: &[u32],
    ) -> Result<()> {
        debug_assert!(indices.len() <= self.k_max);
        // gather sampled rows into the kernel's contiguous tile
        for (j, &i) in indices.iter().enumerate() {
            let row = store.row(i as usize);
            self.xs[j * self.d..(j + 1) * self.d].copy_from_slice(row);
            self.ys[j] = store.y[i as usize];
            self.mask[j] = 1.0;
        }
        for j in indices.len()..self.k_max {
            self.mask[j] = 0.0;
        }
        let inputs = [
            literal_f32(w, &[1, self.d as i64])?,
            literal_f32(&self.xs, &[self.k_max as i64, self.d as i64])?,
            literal_f32(&self.ys, &[self.k_max as i64])?,
            literal_f32(&self.mask, &[self.k_max as i64])?,
            literal_f32(&[self.alpha, self.reg2], &[1, 2])?,
        ];
        let out = self.session.execute("sgd_block", &inputs)?;
        let new_w = to_vec_f32(&out[0])?;
        w.copy_from_slice(&new_w);
        self.calls += 1;
        Ok(())
    }
}

impl BlockExecutor for PjrtExecutor {
    fn run_block(
        &mut self,
        w: &mut Vec<f64>,
        store: StoreView<'_>,
        indices: &[u32],
    ) -> Result<()> {
        let mut w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
        for chunk in indices.chunks(self.k_max) {
            self.run_chunk(&mut w32, store, chunk)?;
        }
        for (dst, &src) in w.iter_mut().zip(&w32) {
            *dst = src as f64;
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeExecutor;
    use crate::model::RidgeModel;
    use crate::runtime::find_artifact_dir;
    use crate::util::rng::Pcg32;

    fn toy_store(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::seeded(seed);
        let x: Vec<f32> =
            (0..n * d).map(|_| rng.next_gaussian() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
        (x, y)
    }

    #[test]
    fn pjrt_matches_native_within_f32_tolerance() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let session = RuntimeSession::open(&dir).unwrap();
        let d = session.manifest.constants.d;
        let (alpha, lambda, n_full) = (1e-3, 0.05, 500);
        let mut pjrt =
            PjrtExecutor::new(session, alpha, lambda, n_full).unwrap();
        let mut native =
            NativeExecutor::new(RidgeModel::new(d, lambda, n_full), alpha);

        let (x, y) = toy_store(200, d, 42);
        let store = StoreView::new(&x, &y, d);
        let mut rng = Pcg32::seeded(7);
        // 700 updates -> exercises the K_MAX=512 chunking path
        let indices: Vec<u32> =
            (0..700).map(|_| rng.gen_range(200) as u32).collect();

        let mut w_p = vec![0.3f64, -0.2, 0.1, 0.0, 0.5, -0.4, 0.25, 0.05];
        let mut w_n = w_p.clone();
        pjrt.run_block(&mut w_p, store, &indices).unwrap();
        native.run_block(&mut w_n, store, &indices).unwrap();
        for j in 0..d {
            assert!(
                (w_p[j] - w_n[j]).abs() < 5e-5,
                "coord {j}: pjrt {} vs native {}",
                w_p[j],
                w_n[j]
            );
        }
        assert!(pjrt.calls() >= 2, "chunking must have split the block");
    }
}
