//! # edgepipe
//!
//! Production-grade reproduction of *"Optimizing Pipelined Computation and
//! Communication for Latency-Constrained Edge Learning"*
//! (N. Skatchkovsky & O. Simeone, 2019).
//!
//! A data-bearing **device** streams its training set to an **edge node**
//! over a channel in blocks of `n_c` samples plus a per-packet overhead
//! `n_o`; the edge node trains by single-sample SGD *while* the next block
//! is on the wire, and everything must finish inside a hard deadline `T`.
//! This crate provides:
//!
//! * the pipelined **coordinator** (device transmitter, channel, edge
//!   trainer) in both a discrete-event and a real threaded form
//!   ([`coordinator`]),
//! * the paper's **Corollary 1 bound** and the block-size optimizer that
//!   picks `ñ_c` ([`bound`]),
//! * a native SGD engine ([`sgd`]) and a PJRT-backed engine ([`runtime`],
//!   [`edge`]) that executes the AOT-compiled JAX/Pallas artifacts built by
//!   `make artifacts`,
//! * every substrate needed offline: RNG, JSON, config, CLI, linear
//!   algebra, dataset synthesis, a bench harness and a property-testing
//!   kit ([`util`], [`linalg`], [`data`], [`bench`], [`testkit`]),
//! * baseline policies and the paper's future-work extensions
//!   ([`baselines`], [`extensions`], [`channel`]).
//!
//! Layering (DESIGN.md): Python/JAX/Pallas exist only at build time; the
//! Rust binary is self-contained once `artifacts/` is built.

pub mod baselines;
pub mod bench;
pub mod bound;
pub mod channel;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod edge;
pub mod extensions;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod protocol;
pub mod runtime;
pub mod sgd;
pub mod sweep;
pub mod testkit;
pub mod util;

/// Crate version, surfaced by `edgepipe info`.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
