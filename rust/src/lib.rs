//! # edgepipe
//!
//! Production-grade reproduction of *"Optimizing Pipelined Computation and
//! Communication for Latency-Constrained Edge Learning"*
//! (N. Skatchkovsky & O. Simeone, 2019).
//!
//! A data-bearing **device** streams its training set to an **edge node**
//! over a channel in blocks of `n_c` samples plus a per-packet overhead
//! `n_o`; the edge node trains by single-sample SGD *while* the next block
//! is on the wire, and everything must finish inside a hard deadline `T`.
//!
//! ## Layering
//!
//! One generic protocol engine, with every variant expressed as a policy
//! (see `ARCHITECTURE.md` for the full picture and a recipe for adding a
//! scenario):
//!
//! * **Scheduler core** ([`coordinator::scheduler`]) — the single
//!   event-driven loop `run_schedule`, advancing normalized time and
//!   dispatching to pluggable traits: `TrafficSource` (who sends which
//!   samples: single device, k-device round-robin, heterogeneous
//!   devices picked by a `DeviceScheduler` — round-robin / greedy /
//!   proportional-fair — online arrivals), `BlockPolicy` (fixed,
//!   adaptive, or the closed-loop channel-adaptive `ControlPolicy` —
//!   an online channel estimator, [`channel::estimator`], feeding the
//!   Corollary-1 remaining-budget re-planner, [`bound::replan`]),
//!   `OverlapMode` (pipelined vs sequential), over the
//!   [`channel`] (including the per-device multi-lane uplink,
//!   [`channel::multilane`]) and [`coordinator::executor`] seams. The hot loop stages blocks in one
//!   reused `BlockFrame` — no per-block allocation — and
//!   `run_schedule_with` threads a reusable `RunWorkspace` through a
//!   whole sweep — no per-run allocation after warm-up (see
//!   ARCHITECTURE.md "Sweep hot path" and
//!   `rust/benches/bench_sweep.rs`).
//! * **Policy adapters** — `coordinator::des::run_des` (the paper's
//!   reference run and Monte-Carlo fast path), [`baselines`]
//!   (sequential, transmit-all-first), [`extensions`] (multi-device,
//!   adaptive schedules, online arrivals, bounded memory, rate
//!   selection): each ~a few dozen lines over the core, bit-identical to
//!   the seed semantics (`rust/tests/scenario_parity.rs`).
//! * **Threaded realization** ([`coordinator::pipeline`]) — a real
//!   two-thread device/edge pipeline with backpressure, bit-identical to
//!   the DES (`rust/tests/pipeline_parity.rs`).
//! * **Scenario registry** ([`sweep::scenario`]) — declarative
//!   (channel × policy × traffic × workload) specs parsed from
//!   config/CLI strings (channels include a Gilbert–Elliott fading
//!   link, [`channel::fading`]; workloads cover ridge regression and
//!   logistic classification, [`model::logistic`]); [`sweep`] runs
//!   Monte-Carlo estimates and grid crossings over any of them in one
//!   parallel fan-out, and the `edgepipe scenario` subcommand exposes
//!   it all.
//! * **Analysis** ([`bound`]) — the paper's Corollary-1 bound, the
//!   block-size optimizer that picks `ñ_c`, the channel-aware
//!   Monte-Carlo validation of the recommendation
//!   ([`bound::validate`], `edgepipe optimize --mc`), and the
//!   fixed-vs-warmup-vs-control comparison sweep across fading
//!   severities ([`sweep::control`], `edgepipe control`).
//! * **Engines** — the native f64 SGD engine ([`sgd`], [`edge`]) and
//!   the batched-seed sweep engine ([`sweep::batch`]): Monte-Carlo
//!   seed-groups traced once each through the DES, then replayed
//!   lane-batched through SoA SGD kernels ([`linalg::batch`],
//!   [`model::lane`]) — bit-identical per seed, `EDGEPIPE_LANES` wide.
//! * **Substrate** — everything needed offline: RNG, JSON, config, CLI,
//!   linear algebra + vectorized f32→f64 kernels ([`linalg::kernels`]),
//!   dataset synthesis, a bench harness (including the tracked sweep
//!   benchmark behind `edgepipe bench`, [`bench::sweep`]) and a
//!   property-testing kit plus the golden-trace snapshot harness
//!   ([`util`], [`linalg`], [`data`], [`bench`], [`testkit`],
//!   [`metrics`], [`protocol`], [`model`]).
//!
//! Python/JAX/Pallas exist only at build time (artifact manifests that
//! [`runtime`] parses); the Rust binary is fully self-contained.

pub mod baselines;
pub mod bench;
pub mod bound;
pub mod channel;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod edge;
pub mod extensions;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod protocol;
pub mod runtime;
pub mod sgd;
pub mod sweep;
pub mod testkit;
pub mod util;

/// Crate version, surfaced by `edgepipe info`.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
