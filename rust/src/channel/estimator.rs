//! Online channel-state estimation from per-packet delivery
//! observations — the sensing half of the closed-loop payload
//! controller.
//!
//! The scheduler core already produces, for every transmitted block, the
//! tuple (nominal duration, measured channel occupancy, ARQ attempt
//! count) — exactly what the edge node observes from ACK timing. This
//! module turns that stream into a slowdown estimate the re-planner
//! (`bound::replan`) can substitute into the Corollary-1 optimizer:
//!
//! * [`GeBeliefEstimator`] — an exact Bayesian filter for the
//!   Gilbert–Elliott channel with KNOWN parameters: a two-state HMM
//!   whose per-packet transition matches `GilbertElliottChannel`'s
//!   clocking, with closed-form belief updates from the geometric ARQ
//!   attempt likelihood and the (state-identifying, when the rates
//!   differ) implied service rate.
//! * [`EmaRateEstimator`] — a moving-average occupancy tracker for
//!   UNKNOWN channels: no model, just an exponentially weighted mean of
//!   the measured per-packet slowdown.
//!
//! Both are deterministic functions of the observation stream — they
//! consume no RNG, so a policy built on them preserves the scheduler's
//! RNG-stream discipline bit for bit (asserted by the ControlPolicy ≡
//! FixedPolicy parity test in `rust/tests/scenario_parity.rs`).

use super::fading::LinkState;

/// What the edge observes about one completed block transmission: the
/// nominal channel time the block would need on the ideal unit-rate
/// link, the time the channel was actually occupied (arrival − send),
/// and the ARQ attempt count carried by the delivery ACK.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PacketObs {
    /// Nominal duration `payload + n_o` (ideal unit-rate link).
    pub nominal: f64,
    /// Measured occupancy: `arrival − sent_at`.
    pub occupancy: f64,
    /// ARQ attempts the delivery took (1 = no loss).
    pub attempts: u32,
}

impl PacketObs {
    /// Measured slowdown of this packet (occupancy per nominal unit).
    pub fn slowdown(&self) -> f64 {
        self.occupancy / self.nominal
    }
}

/// The Gilbert–Elliott parameters the belief filter conditions on
/// (mirrors `GilbertElliottChannel`; a degenerate chain with
/// `p_gb = 0` models any static channel as "pinned good").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GeParams {
    /// P(good → bad) per packet.
    pub p_gb: f64,
    /// P(bad → good) per packet.
    pub p_bg: f64,
    /// Link parameters while good.
    pub good: LinkState,
    /// Link parameters while in a fade.
    pub bad: LinkState,
}

impl GeParams {
    pub fn new(p_gb: f64, p_bg: f64, good: LinkState, bad: LinkState) -> GeParams {
        assert!(
            (0.0..=1.0).contains(&p_gb) && (0.0..=1.0).contains(&p_bg),
            "transition probabilities must be in [0,1], got ({p_gb},{p_bg})"
        );
        GeParams { p_gb, p_bg, good, bad }
    }

    /// Stationary P(bad) — the channel's own closed form
    /// ([`super::fading::stationary_p_bad`]), so filter and channel
    /// share one degenerate-chain convention.
    pub fn stationary_p_bad(&self) -> f64 {
        super::fading::stationary_p_bad(self.p_gb, self.p_bg)
    }

    /// Expected slowdown at bad-state probability `p_bad`.
    fn mix_slowdown(&self, p_bad: f64) -> f64 {
        (1.0 - p_bad) * self.good.expected_slowdown()
            + p_bad * self.bad.expected_slowdown()
    }

    /// Per-state likelihood of one observation: the geometric ARQ
    /// attempt count `p^(a−1)·(1−p)` times an indicator that the
    /// implied service rate (`attempts · nominal / occupancy`) matches
    /// the state's rate. When the two states share a rate the indicator
    /// is uninformative and the attempt count does the discriminating.
    fn likelihood(&self, state: &LinkState, obs: &PacketObs) -> f64 {
        let attempts_lh = if state.p_loss <= 0.0 {
            if obs.attempts == 1 {
                1.0
            } else {
                0.0
            }
        } else {
            state.p_loss.powi(obs.attempts as i32 - 1) * (1.0 - state.p_loss)
        };
        if obs.occupancy <= 0.0 || obs.nominal <= 0.0 {
            return attempts_lh;
        }
        let implied_rate = obs.attempts as f64 * obs.nominal / obs.occupancy;
        let rate_match =
            (implied_rate - state.rate).abs() <= 1e-6 * state.rate;
        if rate_match {
            attempts_lh
        } else {
            0.0
        }
    }
}

/// Exact two-state HMM filter over the Gilbert–Elliott chain: maintains
/// the posterior P(the last packet was transmitted in the bad state)
/// and updates it in closed form per observation. Fresh channels start
/// in the good state (belief 0), matching `GilbertElliottChannel`.
#[derive(Clone, Copy, Debug)]
pub struct GeBeliefEstimator {
    params: GeParams,
    /// Posterior P(bad) for the most recently observed packet.
    belief: f64,
}

impl GeBeliefEstimator {
    pub fn new(params: GeParams) -> GeBeliefEstimator {
        GeBeliefEstimator { params, belief: 0.0 }
    }

    /// Posterior P(bad) of the last observed packet.
    pub fn belief(&self) -> f64 {
        self.belief
    }

    /// Predictive P(bad) for the NEXT packet (one Markov step ahead of
    /// the posterior — the per-packet clocking of the channel).
    pub fn predicted_p_bad(&self) -> f64 {
        self.belief * (1.0 - self.params.p_bg)
            + (1.0 - self.belief) * self.params.p_gb
    }

    /// Fold one packet observation into the belief: predict one Markov
    /// step, then condition on the ARQ/timing likelihoods. If the
    /// observation is impossible under BOTH states (mis-specified
    /// parameters), the likelihood term is skipped and only the
    /// transition prediction is kept.
    pub fn observe(&mut self, obs: &PacketObs) {
        let prior = self.predicted_p_bad();
        let l_bad = self.params.likelihood(&self.params.bad, obs);
        let l_good = self.params.likelihood(&self.params.good, obs);
        let denom = prior * l_bad + (1.0 - prior) * l_good;
        self.belief = if denom > 0.0 {
            prior * l_bad / denom
        } else {
            prior
        };
    }

    /// Expected mean slowdown over the next `horizon` packets given the
    /// current belief: the deviation of the predictive P(bad) from the
    /// stationary distribution decays geometrically with the chain's
    /// mixing factor `λ = 1 − p_gb − p_bg`, so the horizon average has
    /// the closed form `π + (b₁ − π)·(1 − λ^h)/(h(1 − λ))`. `horizon`
    /// is clamped to ≥ 1; as `horizon → ∞` this approaches the
    /// stationary mixture, at `horizon = 1` it is the myopic one-packet
    /// estimate.
    pub fn horizon_slowdown(&self, horizon: f64) -> f64 {
        let h = horizon.max(1.0);
        let pi = self.params.stationary_p_bad();
        let lambda = 1.0 - self.params.p_gb - self.params.p_bg;
        let b1 = self.predicted_p_bad();
        let mixing = if (1.0 - lambda).abs() < 1e-12 {
            1.0 // frozen chain: the deviation never decays
        } else {
            (1.0 - lambda.powf(h)) / (h * (1.0 - lambda))
        };
        let p_bad = (pi + (b1 - pi) * mixing).clamp(0.0, 1.0);
        self.params.mix_slowdown(p_bad)
    }
}

/// Model-free fallback for unknown channels: an exponentially weighted
/// moving average of the measured per-packet slowdown, primed at the
/// scenario's a-priori expected slowdown so the very first plan matches
/// the static recommendation.
#[derive(Clone, Copy, Debug)]
pub struct EmaRateEstimator {
    est: f64,
    weight: f64,
}

impl EmaRateEstimator {
    /// `prior` seeds the estimate; `weight ∈ (0, 1]` is the EMA step
    /// (how fast observations displace the prior).
    pub fn new(prior: f64, weight: f64) -> EmaRateEstimator {
        assert!(prior > 0.0, "prior slowdown must be positive, got {prior}");
        assert!(
            weight > 0.0 && weight <= 1.0,
            "EMA weight must be in (0, 1], got {weight}"
        );
        EmaRateEstimator { est: prior, weight }
    }

    pub fn observe(&mut self, obs: &PacketObs) {
        if obs.nominal <= 0.0 || obs.occupancy <= 0.0 {
            return;
        }
        self.est = (1.0 - self.weight) * self.est
            + self.weight * obs.slowdown();
    }

    pub fn estimate(&self) -> f64 {
        self.est
    }
}

/// The estimator behind a `ControlPolicy`, built by value (no `Box`) so
/// the sweep hot path stays allocation-free.
#[derive(Clone, Copy, Debug)]
pub enum ControlEstimator {
    /// Bayesian Gilbert–Elliott belief filter (known channel params).
    Ge(GeBeliefEstimator),
    /// Moving-average slowdown tracker (unknown channel).
    Ema(EmaRateEstimator),
}

impl ControlEstimator {
    pub fn observe(&mut self, obs: &PacketObs) {
        match self {
            ControlEstimator::Ge(e) => e.observe(obs),
            ControlEstimator::Ema(e) => e.observe(obs),
        }
    }

    /// Expected mean slowdown over the next `horizon` packets (the EMA
    /// estimator has no dynamics and ignores the horizon).
    pub fn horizon_slowdown(&self, horizon: f64) -> f64 {
        match self {
            ControlEstimator::Ge(e) => e.horizon_slowdown(horizon),
            ControlEstimator::Ema(e) => e.estimate(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ControlEstimator::Ge(_) => "ge",
            ControlEstimator::Ema(_) => "ema",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// States distinguished by loss rate only (equal rates), so the
    /// geometric attempt likelihood does all the work — the posteriors
    /// are hand-computable.
    fn loss_only_params() -> GeParams {
        GeParams::new(
            0.2,
            0.5,
            LinkState::new(1.0, 0.1),
            LinkState::new(1.0, 0.6),
        )
    }

    fn obs(nominal: f64, attempts: u32, rate: f64) -> PacketObs {
        PacketObs {
            nominal,
            occupancy: attempts as f64 * nominal / rate,
            attempts,
        }
    }

    #[test]
    fn two_step_posterior_matches_hand_computation() {
        let mut est = GeBeliefEstimator::new(loss_only_params());
        assert_eq!(est.belief(), 0.0, "fresh channels start good");

        // packet 1, one attempt. Predict: b₁ = 0·0.5 + 1·0.2 = 0.2.
        // Likelihoods: L_good = 1−0.1 = 0.9, L_bad = 1−0.6 = 0.4.
        // Posterior: 0.2·0.4 / (0.2·0.4 + 0.8·0.9) = 0.08/0.80 = 0.1.
        est.observe(&obs(5.0, 1, 1.0));
        assert!((est.belief() - 0.1).abs() < 1e-12, "b1 = {}", est.belief());

        // packet 2, three attempts. Predict: 0.1·0.5 + 0.9·0.2 = 0.23.
        // L_good = 0.1²·0.9 = 0.009, L_bad = 0.6²·0.4 = 0.144.
        // Posterior: 0.23·0.144 / (0.23·0.144 + 0.77·0.009)
        //          = 0.03312/0.04005 = 368/445.
        est.observe(&obs(5.0, 3, 1.0));
        assert!(
            (est.belief() - 368.0 / 445.0).abs() < 1e-12,
            "b2 = {}",
            est.belief()
        );
    }

    #[test]
    fn distinct_rates_identify_the_state_exactly() {
        let params = GeParams::new(
            0.3,
            0.4,
            LinkState::new(1.0, 0.0),
            LinkState::new(0.5, 0.0),
        );
        let mut est = GeBeliefEstimator::new(params);
        // occupancy implies rate 0.5 -> only the bad state explains it
        est.observe(&obs(4.0, 1, 0.5));
        assert_eq!(est.belief(), 1.0);
        // next packet at rate 1.0 -> back to certainly good
        est.observe(&obs(4.0, 1, 1.0));
        assert_eq!(est.belief(), 0.0);
    }

    #[test]
    fn pinned_good_chain_never_leaves_belief_zero() {
        // p_gb = 0 models a static channel: whatever the observations,
        // the posterior stays exactly 0 and the slowdown estimate stays
        // exactly the good-state occupancy — the invariant behind the
        // ControlPolicy ≡ FixedPolicy parity on static channels.
        let params = GeParams::new(
            0.0,
            0.7,
            LinkState::new(1.0, 0.3),
            LinkState::new(0.25, 0.9),
        );
        let mut est = GeBeliefEstimator::new(params);
        let s0 = est.horizon_slowdown(1.0);
        assert_eq!(s0, params.good.expected_slowdown());
        for attempts in [1u32, 2, 7, 1, 30] {
            est.observe(&obs(3.0, attempts, 1.0));
            assert_eq!(est.belief(), 0.0);
            assert_eq!(est.horizon_slowdown(10.0), s0);
            assert_eq!(est.horizon_slowdown(1e6), s0);
        }
    }

    #[test]
    fn impossible_observation_keeps_the_transition_prior() {
        // rates match neither state -> likelihoods are both 0; the
        // filter must fall back to the predicted prior, not NaN
        let mut est = GeBeliefEstimator::new(loss_only_params());
        est.observe(&obs(2.0, 1, 0.333));
        assert!((est.belief() - 0.2).abs() < 1e-12, "{}", est.belief());
    }

    #[test]
    fn horizon_average_interpolates_belief_and_stationary() {
        let params = loss_only_params();
        let mut est = GeBeliefEstimator::new(params);
        // a burst of losses drives the belief toward bad
        for _ in 0..4 {
            est.observe(&obs(5.0, 6, 1.0));
        }
        let myopic = est.horizon_slowdown(1.0);
        let long = est.horizon_slowdown(1e9);
        let stationary = params.mix_slowdown(params.stationary_p_bad());
        // belief is above stationary, so the myopic estimate is the
        // most pessimistic and the long-horizon one decays to π
        assert!(est.belief() > params.stationary_p_bad());
        assert!(myopic > long, "{myopic} vs {long}");
        assert!(
            (long - stationary).abs() < 1e-6 * stationary,
            "{long} vs stationary {stationary}"
        );
        // intermediate horizons sit in between
        let mid = est.horizon_slowdown(10.0);
        assert!(mid <= myopic && mid >= long);
    }

    #[test]
    fn ema_tracks_the_measured_slowdown() {
        let mut est = EmaRateEstimator::new(1.0, 0.5);
        assert_eq!(est.estimate(), 1.0);
        est.observe(&PacketObs { nominal: 10.0, occupancy: 30.0, attempts: 3 });
        assert!((est.estimate() - 2.0).abs() < 1e-12);
        est.observe(&PacketObs { nominal: 10.0, occupancy: 30.0, attempts: 3 });
        assert!((est.estimate() - 2.5).abs() < 1e-12);
        // degenerate observations are ignored
        est.observe(&PacketObs { nominal: 0.0, occupancy: 5.0, attempts: 1 });
        assert!((est.estimate() - 2.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn bad_transition_probability_rejected() {
        GeParams::new(
            1.5,
            0.5,
            LinkState::new(1.0, 0.0),
            LinkState::new(1.0, 0.0),
        );
    }
}
