//! Packet-erasure channel with ARQ retransmission (paper Sec. 6 future
//! work: "the inclusion of the effect of delays due to errors in the
//! communication channel").
//!
//! Each transmission attempt is lost i.i.d. with probability `p_loss`;
//! the device retransmits until success (ARQ with instantaneous NACK), so
//! a packet that needed `k` attempts occupies the channel for
//! `k × duration`. The effective rate loss is the expected `1/(1−p)`
//! slowdown — which shifts the optimal block size (bench_channel_error).

use crate::util::rng::Pcg32;

use super::{Channel, Delivery};

/// i.i.d. packet-erasure channel with stop-and-wait ARQ.
#[derive(Clone, Copy, Debug)]
pub struct ErasureChannel {
    /// Per-attempt loss probability in [0, 1).
    pub p_loss: f64,
    /// Cap on attempts (guards pathological RNG streaks; 0 = unlimited).
    pub max_attempts: u32,
}

impl ErasureChannel {
    pub fn new(p_loss: f64) -> ErasureChannel {
        assert!((0.0..1.0).contains(&p_loss), "p_loss must be in [0,1)");
        ErasureChannel { p_loss, max_attempts: 1000 }
    }

    /// Expected slowdown factor 1/(1−p) of this channel.
    pub fn expected_slowdown(&self) -> f64 {
        1.0 / (1.0 - self.p_loss)
    }
}

impl Channel for ErasureChannel {
    fn transmit(
        &mut self,
        sent_at: f64,
        duration: f64,
        rng: &mut Pcg32,
    ) -> Delivery {
        let mut attempts = 1u32;
        while rng.next_f64() < self.p_loss {
            if self.max_attempts > 0 && attempts >= self.max_attempts {
                break;
            }
            attempts += 1;
        }
        Delivery {
            arrival: sent_at + attempts as f64 * duration,
            attempts,
        }
    }

    fn describe(&self) -> String {
        format!("erasure (p_loss={}, ARQ)", self.p_loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_loss_is_ideal() {
        let mut ch = ErasureChannel::new(0.0);
        let mut rng = Pcg32::seeded(1);
        for i in 0..50 {
            let d = ch.transmit(i as f64, 2.0, &mut rng);
            assert_eq!(d.attempts, 1);
            assert_eq!(d.arrival, i as f64 + 2.0);
        }
    }

    #[test]
    fn mean_attempts_matches_geometric() {
        let mut ch = ErasureChannel::new(0.3);
        let mut rng = Pcg32::seeded(2);
        let trials = 20_000;
        let total: u64 = (0..trials)
            .map(|_| ch.transmit(0.0, 1.0, &mut rng).attempts as u64)
            .sum();
        let mean = total as f64 / trials as f64;
        // geometric mean 1/(1-p) = 1.4286
        assert!((mean - ch.expected_slowdown()).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn arrival_scales_with_attempts() {
        let mut ch = ErasureChannel::new(0.9);
        let mut rng = Pcg32::seeded(3);
        let d = ch.transmit(5.0, 2.0, &mut rng);
        assert_eq!(d.arrival, 5.0 + d.attempts as f64 * 2.0);
        assert!(d.attempts >= 1);
    }

    #[test]
    #[should_panic]
    fn p_one_rejected() {
        ErasureChannel::new(1.0);
    }
}
