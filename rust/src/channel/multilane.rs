//! Heterogeneous multi-lane uplink: one independent link per device.
//!
//! The paper's Sec. 6 multi-device extension shares ONE channel between
//! all devices. Real edge fleets are heterogeneous — each device sees its
//! own rate, loss and fading process — so [`MultiLaneChannel`] wraps one
//! inner [`Channel`] per device ("lane") and routes every packet through
//! the transmitting device's lane. The uplink stays serialized (the
//! scheduler core still sends one block at a time and advances `t_send`
//! to the arrival), but each lane keeps its own link parameters and its
//! own state (e.g. a per-device Gilbert–Elliott fade).
//!
//! Routing is driven by the scheduler loop through
//! [`Channel::select_lane`]: after the traffic source picks the next
//! device, the loop selects that device's lane before calling
//! [`transmit`](Channel::transmit). Two invariants keep the determinism
//! contract intact:
//!
//! * `select_lane` consumes no randomness — all channel noise still
//!   comes from the single `STREAM_CHANNEL` RNG, drawn in transmission
//!   order exactly as for a shared channel;
//! * a single-lane `MultiLaneChannel` is draw-for-draw identical to its
//!   inner channel, so the heterogeneous `k = 1` scenario stays
//!   bit-identical to `run_des` (asserted in
//!   `rust/tests/scenario_parity.rs`).

use crate::util::rng::Pcg32;

use super::{Channel, Delivery};

/// Per-device links for the heterogeneous multi-device uplink.
pub struct MultiLaneChannel<C: Channel> {
    lanes: Vec<C>,
    active: usize,
}

impl<C: Channel> MultiLaneChannel<C> {
    /// Wrap one channel per device; lane 0 starts active.
    pub fn new(lanes: Vec<C>) -> MultiLaneChannel<C> {
        assert!(!lanes.is_empty(), "need at least one lane");
        MultiLaneChannel { lanes, active: 0 }
    }

    /// Build `k` lanes from a per-device factory — the fleet-scale
    /// constructor behind the sharded-DES device-count scaling bench
    /// (`bench/sweep.rs`), where `k` reaches 10k+ lanes. The factory
    /// must be deterministic in the lane index: lane channels carry
    /// per-device STATE, never their own RNG (all channel noise stays
    /// on the single `STREAM_CHANNEL` sequence), so building a fleet
    /// consumes no randomness regardless of `k`.
    pub fn uniform(
        k: usize,
        mut make: impl FnMut(usize) -> C,
    ) -> MultiLaneChannel<C> {
        Self::new((0..k).map(&mut make).collect())
    }

    /// Number of lanes.
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// The currently selected lane index.
    pub fn active_lane(&self) -> usize {
        self.active
    }

    /// Borrow the per-lane channels (test/diagnostic hook).
    pub fn lanes(&self) -> &[C] {
        &self.lanes
    }

    /// Recover the per-lane channels (buffer recycling).
    pub fn into_lanes(self) -> Vec<C> {
        self.lanes
    }
}

impl<C: Channel> Channel for MultiLaneChannel<C> {
    fn transmit(
        &mut self,
        sent_at: f64,
        duration: f64,
        rng: &mut Pcg32,
    ) -> Delivery {
        self.lanes[self.active].transmit(sent_at, duration, rng)
    }

    fn describe(&self) -> String {
        let lanes: Vec<String> =
            self.lanes.iter().map(|l| l.describe()).collect();
        format!("multi-lane [{}]", lanes.join(" | "))
    }

    fn select_lane(&mut self, lane: usize) {
        assert!(
            lane < self.lanes.len(),
            "lane {lane} out of range (have {})",
            self.lanes.len()
        );
        self.active = lane;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{ErasureChannel, IdealChannel, RateLimitedChannel};

    #[test]
    fn routes_packets_through_the_selected_lane() {
        // lane 0 at rate 1, lane 1 at rate 0.5: the same packet takes
        // twice as long on lane 1
        let mut ch = MultiLaneChannel::new(vec![
            RateLimitedChannel::new(1.0, IdealChannel),
            RateLimitedChannel::new(0.5, IdealChannel),
        ]);
        let mut rng = Pcg32::seeded(1);
        ch.select_lane(0);
        assert_eq!(ch.transmit(0.0, 4.0, &mut rng).arrival, 4.0);
        ch.select_lane(1);
        assert_eq!(ch.transmit(4.0, 4.0, &mut rng).arrival, 12.0);
        assert_eq!(ch.active_lane(), 1);
    }

    #[test]
    fn single_lane_is_stream_identical_to_the_inner_channel() {
        let p = 0.3;
        let mut multi = MultiLaneChannel::new(vec![ErasureChannel::new(p)]);
        let mut plain = ErasureChannel::new(p);
        let mut rng_a = Pcg32::new(7, 4);
        let mut rng_b = Pcg32::new(7, 4);
        for i in 0..300 {
            let t = i as f64 * 2.0;
            multi.select_lane(0);
            let a = multi.transmit(t, 1.5, &mut rng_a);
            let b = plain.transmit(t, 1.5, &mut rng_b);
            assert_eq!(a, b, "packet {i} diverged");
        }
    }

    #[test]
    fn lanes_keep_independent_state() {
        use crate::channel::{GilbertElliottChannel, LinkState};
        // lane 0 flips state every packet; lane 1 never leaves good.
        // Routing through lane 1 must not advance lane 0's chain.
        let flippy = GilbertElliottChannel::new(
            1.0,
            1.0,
            LinkState::new(1.0, 0.0),
            LinkState::new(0.5, 0.0),
        );
        let pinned = GilbertElliottChannel::new(
            0.0,
            0.0,
            LinkState::new(1.0, 0.0),
            LinkState::new(0.5, 0.0),
        );
        let mut ch = MultiLaneChannel::new(vec![flippy, pinned]);
        let mut rng = Pcg32::seeded(3);
        ch.select_lane(0);
        ch.transmit(0.0, 1.0, &mut rng);
        assert!(ch.lanes()[0].is_bad(), "lane 0 flipped into the fade");
        ch.select_lane(1);
        for _ in 0..5 {
            ch.transmit(0.0, 1.0, &mut rng);
        }
        assert!(ch.lanes()[0].is_bad(), "lane 1 traffic advanced lane 0");
        assert!(!ch.lanes()[1].is_bad());
    }

    #[test]
    fn uniform_builds_k_lanes_from_the_factory() {
        let ch = MultiLaneChannel::uniform(257, |i| {
            RateLimitedChannel::new(1.0 + i as f64, IdealChannel)
        });
        assert_eq!(ch.lane_count(), 257);
        assert_eq!(ch.active_lane(), 0);
    }

    #[test]
    #[should_panic]
    fn out_of_range_lane_is_rejected() {
        let mut ch = MultiLaneChannel::new(vec![IdealChannel]);
        ch.select_lane(1);
    }

    #[test]
    #[should_panic]
    fn empty_lane_set_is_rejected() {
        MultiLaneChannel::<IdealChannel>::new(Vec::new());
    }
}
