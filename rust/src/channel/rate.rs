//! Rate-limited channel: a wrapper scaling transmission durations.
//!
//! Models a link whose rate differs from the 1-sample-per-unit
//! normalization, and is the substrate for the rate-selection extension
//! (paper Sec. 6: "the optimization problem could be generalized to
//! account for the selection of the data rate"): a lower rate shrinks the
//! erasure probability in `extensions::rate_select`.

use crate::util::rng::Pcg32;

use super::{Channel, Delivery};

/// Wraps an inner channel, scaling every duration by `1/rate`.
pub struct RateLimitedChannel<C: Channel> {
    /// Relative rate (1.0 = the paper's normalization).
    pub rate: f64,
    inner: C,
}

impl<C: Channel> RateLimitedChannel<C> {
    pub fn new(rate: f64, inner: C) -> RateLimitedChannel<C> {
        assert!(rate > 0.0, "rate must be positive");
        RateLimitedChannel { rate, inner }
    }
}

impl<C: Channel> Channel for RateLimitedChannel<C> {
    fn transmit(
        &mut self,
        sent_at: f64,
        duration: f64,
        rng: &mut Pcg32,
    ) -> Delivery {
        self.inner.transmit(sent_at, duration / self.rate, rng)
    }

    fn describe(&self) -> String {
        format!("rate={} over {}", self.rate, self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;

    #[test]
    fn slower_rate_stretches_duration() {
        let mut ch = RateLimitedChannel::new(0.5, IdealChannel);
        let mut rng = Pcg32::seeded(1);
        let d = ch.transmit(0.0, 3.0, &mut rng);
        assert_eq!(d.arrival, 6.0);
    }

    #[test]
    fn faster_rate_shrinks_duration() {
        let mut ch = RateLimitedChannel::new(2.0, IdealChannel);
        let mut rng = Pcg32::seeded(1);
        let d = ch.transmit(1.0, 3.0, &mut rng);
        assert_eq!(d.arrival, 2.5);
    }
}
