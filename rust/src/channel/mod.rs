//! Communication-channel substrate.
//!
//! The paper's main analysis assumes an error-free channel (Sec. 2); its
//! Sec. 6 lists channel errors and rate selection as future work — both
//! are implemented here as drop-in [`Channel`] implementations so the
//! coordinator, benches and the ablations can exercise them, along with
//! a bursty Gilbert–Elliott fading channel ([`fading`]) whose good/bad
//! Markov states model the time-varying links of real edge deployments,
//! and a heterogeneous multi-lane uplink ([`multilane`]) giving every
//! device of a multi-device scenario its own link. The [`estimator`]
//! module closes the loop from the other side: online channel-state
//! estimation (a Gilbert–Elliott belief filter and a moving-average
//! rate tracker) from the per-packet delivery observations the
//! scheduler produces. The [`fault`] module scripts deterministic fault
//! injection — link outages, ACK loss, permanent device dropout,
//! trainer preemption — over any of these via a [`FaultPlan`] wrapper.

pub mod erasure;
pub mod estimator;
pub mod fading;
pub mod fault;
pub mod ideal;
pub mod multilane;
pub mod rate;

pub use erasure::ErasureChannel;
pub use estimator::{
    ControlEstimator, EmaRateEstimator, GeBeliefEstimator, GeParams,
    PacketObs,
};
pub use fault::{FaultPlan, FaultSpec, FaultTolerance, FaultWindow, RetrySpec};
pub use fading::{GilbertElliottChannel, LinkState};
pub use ideal::IdealChannel;
pub use multilane::MultiLaneChannel;
pub use rate::RateLimitedChannel;

use crate::util::rng::Pcg32;

/// Result of pushing one packet through a channel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Delivery {
    /// Time the packet becomes available at the edge node.
    pub arrival: f64,
    /// Number of transmission attempts (1 = no loss).
    pub attempts: u32,
}

/// A device → edge channel: maps (send time, duration) to an arrival.
///
/// Implementations must be monotone: a packet sent later never arrives
/// earlier (verified by property tests).
pub trait Channel: Send {
    /// Transmit a packet occupying the channel for `duration` starting at
    /// `sent_at`; returns when it is fully received. The channel is busy
    /// until `Delivery::arrival` (the caller serializes transmissions).
    fn transmit(
        &mut self,
        sent_at: f64,
        duration: f64,
        rng: &mut Pcg32,
    ) -> Delivery;

    /// Human-readable description for logs.
    fn describe(&self) -> String;

    /// Route subsequent transmissions through device `lane`'s link (the
    /// heterogeneous multi-device uplink, [`MultiLaneChannel`]). The
    /// scheduler core calls this once per block, before
    /// [`transmit`](Channel::transmit), with the transmitting device's
    /// index. Single-link channels ignore it (default no-op); an
    /// implementation must consume no randomness here, so routing never
    /// perturbs the `STREAM_CHANNEL` RNG discipline.
    fn select_lane(&mut self, _lane: usize) {}
}
