//! The paper's error-free channel: arrival = sent_at + duration.

use crate::util::rng::Pcg32;

use super::{Channel, Delivery};

/// Error-free, unit-rate channel (paper Sec. 2).
#[derive(Clone, Copy, Debug, Default)]
pub struct IdealChannel;

impl Channel for IdealChannel {
    fn transmit(
        &mut self,
        sent_at: f64,
        duration: f64,
        _rng: &mut Pcg32,
    ) -> Delivery {
        Delivery { arrival: sent_at + duration, attempts: 1 }
    }

    fn describe(&self) -> String {
        "ideal (error-free, unit rate)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_is_exact() {
        let mut ch = IdealChannel;
        let mut rng = Pcg32::seeded(0);
        let d = ch.transmit(10.0, 5.5, &mut rng);
        assert_eq!(d, Delivery { arrival: 15.5, attempts: 1 });
    }
}
