//! Deterministic fault injection over any [`Channel`].
//!
//! The paper's impairment models (erasures, fading) are *benign*: every
//! packet eventually gets through, so the bias/variance tradeoff under
//! persistent failures — device loss, link outages, lost ACKs, a
//! preempted trainer — is invisible. [`FaultPlan`] wraps any channel and
//! injects **scripted, fully deterministic** faults on top of it,
//! parameterized by a [`FaultSpec`] parsed from the `fault=<spec>`
//! suffix of the scenario channel grammar.
//!
//! Fault taxonomy (clauses, composable with `+`):
//!
//! * `outage:<start>:<dur>[:<period>]` — a burst window in which every
//!   transmission attempt fails. The sender retries back-to-back, so a
//!   packet hitting the window burns `ceil(window_left / duration)`
//!   attempts and starts for real once the window ends. Omitting
//!   `period` makes the window one-shot; with it, the outage re-fires
//!   every `period` time units (`period > dur`).
//! * `ackloss:<p>` — the edge received the packet but the ACK is lost
//!   with probability `p`; the device retransmits the whole block.
//! * `drop:<device>:<t>` — device `device`'s link dies permanently at
//!   time `t`: any attempt at or after `t` never arrives
//!   (`arrival = ∞`). This is the hook the scheduler's timeout/eviction
//!   machinery reacts to.
//! * `preempt:<start>:<dur>[:<period>]` — trainer-side compute
//!   preemption: SGD is frozen during the window (the scheduler's clock
//!   still advances). Carried to the trainer via
//!   [`FaultTolerance::preempt`].
//! * `retry:<timeout>[:<budget>[:<evict>]]` — protocol-hardening knobs
//!   (not a fault): per-packet timeout as a multiple of the nominal
//!   duration, max timed-out re-sends per block, and eviction after
//!   that many *consecutive* timeouts per device.
//!
//! RNG-stream discipline: faults draw from the same `STREAM_CHANNEL`
//! RNG the wrapped channel uses, in transmission order — and a clause
//! that cannot fire draws **nothing**. A disabled [`FaultPlan`] (empty
//! [`FaultSpec`]) is therefore draw-for-draw identical to its inner
//! channel, which is what keeps every fault-free scenario bit-identical
//! with the fault layer compiled in (`fault=off` parses back to the
//! bare channel spec and never even constructs a `FaultPlan`).

use anyhow::{bail, Context, Result};

use crate::util::rng::Pcg32;

use super::{Channel, Delivery};

/// Default `retry` budget when the clause omits it.
pub const DEFAULT_RETRY_BUDGET: u32 = 3;

/// Give up and report a dead link once an outage has burned this many
/// back-to-back attempts (guards pathological window/duration combos
/// where the gaps between periodic windows are narrower than one
/// packet).
pub const MAX_OUTAGE_ATTEMPTS: u32 = 10_000;

/// One scripted fault window, optionally periodic.
///
/// Active at `t` iff `t >= start` and `(t - start) mod period < dur`
/// (`period = ∞` — the one-shot form — degenerates to
/// `start <= t < start + dur`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultWindow {
    pub start: f64,
    pub dur: f64,
    /// Re-fire interval; `f64::INFINITY` = one-shot.
    pub period: f64,
}

impl FaultWindow {
    pub fn new(start: f64, dur: f64, period: f64) -> Result<FaultWindow> {
        if !(start >= 0.0 && start.is_finite()) {
            bail!("fault window start must be finite and >= 0, got {start}");
        }
        if !(dur > 0.0 && dur.is_finite()) {
            bail!("fault window duration must be finite and > 0, got {dur}");
        }
        if !(period > dur) {
            bail!(
                "fault window period ({period}) must exceed its duration \
                 ({dur}) so gaps exist"
            );
        }
        Ok(FaultWindow { start, dur, period })
    }

    /// One-shot window `[start, start + dur)`.
    pub fn once(start: f64, dur: f64) -> Result<FaultWindow> {
        FaultWindow::new(start, dur, f64::INFINITY)
    }

    /// Is `t` inside an occurrence of this window?
    pub fn active(&self, t: f64) -> bool {
        self.end_if_active(t).is_some()
    }

    /// If `t` falls inside an occurrence, the end time of that
    /// occurrence.
    pub fn end_if_active(&self, t: f64) -> Option<f64> {
        self.occurrence_at_or_after(t)
            .filter(|&(w_start, _)| w_start <= t)
            .map(|(_, w_end)| w_end)
    }

    /// The earliest occurrence `(start, end)` that covers `t` or begins
    /// after it (`None` once a one-shot window is in the past).
    pub fn occurrence_at_or_after(&self, t: f64) -> Option<(f64, f64)> {
        if !t.is_finite() || t <= self.start {
            return Some((self.start, self.start + self.dur))
                .filter(|_| t.is_finite());
        }
        let k = if self.period.is_finite() {
            ((t - self.start) / self.period).floor()
        } else {
            0.0
        };
        let w_start = self.start + k * self.period;
        if t < w_start + self.dur {
            return Some((w_start, w_start + self.dur));
        }
        if self.period.is_finite() {
            let next = w_start + self.period;
            Some((next, next + self.dur))
        } else {
            None
        }
    }

    fn label(&self, kind: &str) -> String {
        if self.period.is_finite() {
            format!("{kind}:{}:{}:{}", self.start, self.dur, self.period)
        } else {
            format!("{kind}:{}:{}", self.start, self.dur)
        }
    }
}

/// The earliest occurrence among `windows` that covers `t` or begins
/// after it.
pub fn next_window(windows: &[FaultWindow], t: f64) -> Option<(f64, f64)> {
    windows
        .iter()
        .filter_map(|w| w.occurrence_at_or_after(t))
        .min_by(|a, b| a.0.total_cmp(&b.0))
}

/// Latest end among windows active at `t` (`None` = no window active).
fn active_window_end(windows: &[FaultWindow], t: f64) -> Option<f64> {
    windows
        .iter()
        .filter_map(|w| w.end_if_active(t))
        .max_by(f64::total_cmp)
}

/// Protocol-hardening knobs carried by a `retry`/`preempt` clause.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetrySpec {
    /// Per-packet timeout as a multiple of the nominal duration (> 1).
    pub timeout: f64,
    /// Max timed-out re-sends of one block before it is abandoned.
    pub budget: u32,
    /// Evict a device after this many consecutive timeouts (0 = never).
    pub evict: u32,
}

/// Scheduler/trainer-side fault-tolerance configuration, extracted from
/// a [`FaultSpec`] and threaded through `DesConfig`. All-default means
/// the paper's original unbounded-ARQ, never-preempted protocol.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultTolerance {
    /// Per-packet ARQ timeout as a multiple of the block's nominal
    /// duration; `0` disables the whole timeout/retry/eviction
    /// machinery.
    pub timeout_mult: f64,
    /// Max timed-out re-sends per block before it is abandoned.
    pub retry_budget: u32,
    /// Evict a device after this many consecutive timeouts (0 = never).
    pub evict_after: u32,
    /// Trainer-side compute-preemption windows.
    pub preempt: Vec<FaultWindow>,
}

impl FaultTolerance {
    /// Is the timeout/retry/eviction machinery armed?
    pub fn enabled(&self) -> bool {
        self.timeout_mult > 0.0
    }

    /// Nothing to thread into a run (the fault-free default).
    pub fn is_trivial(&self) -> bool {
        !self.enabled() && self.preempt.is_empty()
    }
}

/// A parsed `fault=<spec>` suffix: the full scripted-fault plan for one
/// scenario, plus the protocol knobs riding along in `retry:`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Link-outage burst windows (every attempt inside fails).
    pub outages: Vec<FaultWindow>,
    /// ACK-loss probability in [0, 1).
    pub ack_loss: f64,
    /// `(device, t)`: device's link dies permanently at `t`.
    pub drops: Vec<(usize, f64)>,
    /// Trainer-side compute-preemption windows.
    pub preempts: Vec<FaultWindow>,
    /// Protocol-hardening knobs.
    pub retry: Option<RetrySpec>,
}

const FAULT_GRAMMAR: &str = "expected fault=<clause>[+<clause>...] with \
clauses outage:<start>:<dur>[:<period>] | ackloss:<p> | \
drop:<device>:<t> | preempt:<start>:<dur>[:<period>] | \
retry:<timeout>[:<budget>[:<evict>]] | off";

fn parse_f64(part: &str, what: &str) -> Result<f64> {
    part.parse::<f64>()
        .with_context(|| format!("bad {what} '{part}' ({FAULT_GRAMMAR})"))
}

fn parse_window(parts: &[&str], kind: &str) -> Result<FaultWindow> {
    if parts.len() < 2 || parts.len() > 3 {
        bail!("{kind} needs 2-3 fields ({FAULT_GRAMMAR})");
    }
    let start = parse_f64(parts[0], &format!("{kind} start"))?;
    let dur = parse_f64(parts[1], &format!("{kind} duration"))?;
    let period = match parts.get(2) {
        Some(p) => parse_f64(p, &format!("{kind} period"))?,
        None => f64::INFINITY,
    };
    FaultWindow::new(start, dur, period)
}

impl FaultSpec {
    /// Parse the payload of a `fault=` suffix. `off` (or the empty
    /// string) is the canonical disabled spec.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        if s.is_empty() || s == "off" {
            return Ok(spec);
        }
        for clause in s.split('+') {
            let parts: Vec<&str> = clause.split(':').collect();
            let (kind, rest) = (parts[0], &parts[1..]);
            match kind {
                "outage" => spec.outages.push(parse_window(rest, "outage")?),
                "preempt" => {
                    spec.preempts.push(parse_window(rest, "preempt")?)
                }
                "ackloss" => {
                    if rest.len() != 1 {
                        bail!("ackloss needs 1 field ({FAULT_GRAMMAR})");
                    }
                    if spec.ack_loss > 0.0 {
                        bail!("duplicate ackloss clause in '{s}'");
                    }
                    let p = parse_f64(rest[0], "ackloss probability")?;
                    if !(0.0..1.0).contains(&p) {
                        bail!("ackloss probability must be in [0,1), got {p}");
                    }
                    spec.ack_loss = p;
                }
                "drop" => {
                    if rest.len() != 2 {
                        bail!("drop needs 2 fields ({FAULT_GRAMMAR})");
                    }
                    let device =
                        rest[0].parse::<usize>().with_context(|| {
                            format!(
                                "bad drop device '{}' ({FAULT_GRAMMAR})",
                                rest[0]
                            )
                        })?;
                    let t = parse_f64(rest[1], "drop time")?;
                    if !(t >= 0.0 && t.is_finite()) {
                        bail!("drop time must be finite and >= 0, got {t}");
                    }
                    spec.drops.push((device, t));
                }
                "retry" => {
                    if rest.is_empty() || rest.len() > 3 {
                        bail!("retry needs 1-3 fields ({FAULT_GRAMMAR})");
                    }
                    if spec.retry.is_some() {
                        bail!("duplicate retry clause in '{s}'");
                    }
                    let timeout = parse_f64(rest[0], "retry timeout")?;
                    if !(timeout > 1.0 && timeout.is_finite()) {
                        bail!(
                            "retry timeout must be a finite multiple > 1 of \
                             the nominal duration, got {timeout}"
                        );
                    }
                    let budget = match rest.get(1) {
                        Some(b) => b.parse::<u32>().with_context(|| {
                            format!("bad retry budget '{b}' ({FAULT_GRAMMAR})")
                        })?,
                        None => DEFAULT_RETRY_BUDGET,
                    };
                    let evict = match rest.get(2) {
                        Some(e) => e.parse::<u32>().with_context(|| {
                            format!(
                                "bad retry evict count '{e}' ({FAULT_GRAMMAR})"
                            )
                        })?,
                        None => 0,
                    };
                    spec.retry = Some(RetrySpec { timeout, budget, evict });
                }
                other => bail!("unknown fault clause '{other}' ({FAULT_GRAMMAR})"),
            }
        }
        Ok(spec)
    }

    /// No clause can ever fire (the canonical `off`).
    pub fn is_disabled(&self) -> bool {
        self.outages.is_empty()
            && self.ack_loss == 0.0
            && self.drops.is_empty()
            && self.preempts.is_empty()
            && self.retry.is_none()
    }

    /// Canonical label, round-tripping through [`FaultSpec::parse`].
    /// Clause order is normalized to outage, ackloss, drop, preempt,
    /// retry.
    pub fn label(&self) -> String {
        if self.is_disabled() {
            return "off".to_string();
        }
        let mut clauses: Vec<String> = Vec::new();
        for w in &self.outages {
            clauses.push(w.label("outage"));
        }
        if self.ack_loss > 0.0 {
            clauses.push(format!("ackloss:{}", self.ack_loss));
        }
        for &(device, t) in &self.drops {
            clauses.push(format!("drop:{device}:{t}"));
        }
        for w in &self.preempts {
            clauses.push(w.label("preempt"));
        }
        if let Some(r) = &self.retry {
            let mut c = format!("retry:{}", r.timeout);
            if r.budget != DEFAULT_RETRY_BUDGET || r.evict != 0 {
                c.push_str(&format!(":{}", r.budget));
            }
            if r.evict != 0 {
                c.push_str(&format!(":{}", r.evict));
            }
            clauses.push(c);
        }
        clauses.join("+")
    }

    /// The scheduler/trainer-side knobs this spec carries.
    pub fn tolerance(&self) -> FaultTolerance {
        let (timeout_mult, retry_budget, evict_after) = match self.retry {
            Some(r) => (r.timeout, r.budget, r.evict),
            None => (0.0, 0, 0),
        };
        FaultTolerance {
            timeout_mult,
            retry_budget,
            evict_after,
            preempt: self.preempts.clone(),
        }
    }

    /// The channel-side clauses only (what [`FaultPlan`] acts on).
    pub fn has_channel_faults(&self) -> bool {
        !self.outages.is_empty()
            || self.ack_loss > 0.0
            || !self.drops.is_empty()
    }
}

/// A fault-injecting wrapper over any [`Channel`].
///
/// The wrapped channel's own noise model still runs underneath; the
/// plan scripts *additional* impairments on top. Which device the
/// current packet belongs to comes from [`Channel::select_lane`]
/// (shared-uplink scenarios) or is pinned at construction with
/// [`FaultPlan::for_lane`] (per-lane plans inside a
/// [`MultiLaneChannel`](super::MultiLaneChannel), which never forwards
/// `select_lane` to its children).
pub struct FaultPlan<C: Channel> {
    inner: C,
    spec: FaultSpec,
    lane: usize,
}

impl<C: Channel> FaultPlan<C> {
    pub fn new(spec: FaultSpec, inner: C) -> FaultPlan<C> {
        FaultPlan { inner, spec, lane: 0 }
    }

    /// Pin the plan to device `lane` (for per-lane plans whose parent
    /// routes packets without forwarding `select_lane`).
    pub fn for_lane(mut self, lane: usize) -> FaultPlan<C> {
        self.lane = lane;
        self
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    pub fn inner(&self) -> &C {
        &self.inner
    }

    /// Has the active device's link permanently died by `t`?
    fn lane_dropped(&self, t: f64) -> bool {
        self.spec
            .drops
            .iter()
            .any(|&(device, at)| device == self.lane && t >= at)
    }

    /// One full send: wait out outages (burning back-to-back failed
    /// attempts), then run the inner channel. Draws randomness only via
    /// the inner channel.
    fn transmit_once(
        &mut self,
        sent_at: f64,
        duration: f64,
        rng: &mut Pcg32,
    ) -> Delivery {
        if self.lane_dropped(sent_at) {
            return Delivery { arrival: f64::INFINITY, attempts: 1 };
        }
        let mut start = sent_at;
        let mut burned = 0u32;
        while let Some(end) = active_window_end(&self.spec.outages, start) {
            // every attempt inside the window fails; the sender retries
            // back-to-back, so it burns ceil(window_left / duration)
            // attempts and next tries at or past the window end
            let k = ((end - start) / duration).ceil().max(1.0);
            burned = burned.saturating_add(k.min(u32::MAX as f64) as u32);
            if burned >= MAX_OUTAGE_ATTEMPTS {
                return Delivery { arrival: f64::INFINITY, attempts: burned };
            }
            start += k * duration;
            if self.lane_dropped(start) {
                return Delivery { arrival: f64::INFINITY, attempts: burned };
            }
        }
        let d = self.inner.transmit(start, duration, rng);
        Delivery {
            arrival: d.arrival,
            attempts: d.attempts.saturating_add(burned),
        }
    }
}

impl<C: Channel> Channel for FaultPlan<C> {
    fn transmit(
        &mut self,
        sent_at: f64,
        duration: f64,
        rng: &mut Pcg32,
    ) -> Delivery {
        let mut d = self.transmit_once(sent_at, duration, rng);
        // ACK loss: the payload arrived but the ACK didn't; the device
        // retransmits the whole block from the (would-be) arrival. The
        // branch draws randomness ONLY when the clause is armed.
        if self.spec.ack_loss > 0.0 {
            while d.arrival.is_finite()
                && rng.next_f64() < self.spec.ack_loss
            {
                let re = self.transmit_once(d.arrival, duration, rng);
                d = Delivery {
                    arrival: re.arrival,
                    attempts: d.attempts.saturating_add(re.attempts),
                };
            }
        }
        d
    }

    fn describe(&self) -> String {
        format!("{} + faults({})", self.inner.describe(), self.spec.label())
    }

    fn select_lane(&mut self, lane: usize) {
        self.lane = lane;
        self.inner.select_lane(lane);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{ErasureChannel, IdealChannel};

    // ------------------------------------------------------- grammar

    #[test]
    fn off_and_empty_parse_disabled() {
        assert!(FaultSpec::parse("off").unwrap().is_disabled());
        assert!(FaultSpec::parse("").unwrap().is_disabled());
        assert_eq!(FaultSpec::default().label(), "off");
    }

    #[test]
    fn clauses_parse_and_labels_round_trip() {
        let cases = [
            "outage:100:25",
            "outage:100:25:200",
            "ackloss:0.3",
            "drop:2:150",
            "preempt:50:10:120",
            "retry:4",
            "retry:4:6",
            "retry:4:3:2",
            "outage:10:5+ackloss:0.1+drop:0:90+preempt:0:1:30+retry:2.5:1:4",
        ];
        for s in cases {
            let spec = FaultSpec::parse(s).unwrap();
            assert!(!spec.is_disabled(), "'{s}' parsed as disabled");
            let label = spec.label();
            let re = FaultSpec::parse(&label)
                .unwrap_or_else(|e| panic!("label '{label}' unparseable: {e}"));
            assert_eq!(spec, re, "'{s}' -> '{label}' round-trip diverged");
            assert_eq!(re.label(), label, "label not canonical for '{s}'");
        }
    }

    #[test]
    fn retry_label_drops_suffix_defaults() {
        let spec = FaultSpec::parse("retry:4:3").unwrap();
        assert_eq!(spec.label(), "retry:4");
        let spec = FaultSpec::parse("retry:4:3:0").unwrap();
        assert_eq!(spec.label(), "retry:4");
        // a non-default evict forces the budget field to stay
        let spec = FaultSpec::parse("retry:4:3:2").unwrap();
        assert_eq!(spec.label(), "retry:4:3:2");
    }

    #[test]
    fn malformed_specs_are_rejected_with_the_grammar() {
        for bad in [
            "nonsense:1",
            "outage:5",
            "outage:-1:5",
            "outage:0:5:4",     // period <= dur
            "ackloss:1.0",
            "ackloss:0.1+ackloss:0.2",
            "drop:x:5",
            "drop:1:-3",
            "retry:1",          // timeout must exceed 1
            "retry:inf",
            "retry:2+retry:3",
        ] {
            let err = FaultSpec::parse(bad).unwrap_err().to_string();
            assert!(
                !err.is_empty(),
                "'{bad}' should fail with a grammar message"
            );
        }
        let err =
            FaultSpec::parse("bogus:1").unwrap_err().to_string();
        assert!(
            err.contains("outage") && err.contains("retry"),
            "unknown-clause error must list the valid clauses: {err}"
        );
    }

    #[test]
    fn tolerance_extracts_the_protocol_knobs() {
        let spec = FaultSpec::parse("retry:3:5:2+preempt:10:2:40").unwrap();
        let tol = spec.tolerance();
        assert_eq!(tol.timeout_mult, 3.0);
        assert_eq!(tol.retry_budget, 5);
        assert_eq!(tol.evict_after, 2);
        assert_eq!(tol.preempt.len(), 1);
        assert!(tol.enabled() && !tol.is_trivial());
        assert!(FaultSpec::parse("outage:5:1").unwrap().tolerance().is_trivial());
        assert!(FaultTolerance::default().is_trivial());
    }

    // ------------------------------------------------------- windows

    #[test]
    fn window_activity_math() {
        let w = FaultWindow::new(100.0, 25.0, 200.0).unwrap();
        assert!(!w.active(99.9));
        assert!(w.active(100.0));
        assert!(w.active(124.9));
        assert!(!w.active(125.0));
        // periodic re-fire
        assert!(w.active(300.0) && w.active(324.9) && !w.active(325.0));
        assert_eq!(w.end_if_active(310.0), Some(325.0));
        assert_eq!(w.occurrence_at_or_after(130.0), Some((300.0, 325.0)));

        let once = FaultWindow::once(50.0, 10.0).unwrap();
        assert!(once.active(55.0) && !once.active(60.0));
        assert_eq!(once.occurrence_at_or_after(61.0), None);
        assert_eq!(once.occurrence_at_or_after(10.0), Some((50.0, 60.0)));
        assert_eq!(next_window(&[w, once], 0.0), Some((50.0, 60.0)));
        assert_eq!(next_window(&[w, once], 70.0), Some((100.0, 125.0)));
        assert_eq!(next_window(&[], 0.0), None);
    }

    // ------------------------------------------------ fault behavior

    #[test]
    fn disabled_plan_is_stream_identical_to_the_inner_channel() {
        let p = 0.3;
        let mut plan =
            FaultPlan::new(FaultSpec::default(), ErasureChannel::new(p));
        let mut plain = ErasureChannel::new(p);
        let mut rng_a = Pcg32::new(7, 4);
        let mut rng_b = Pcg32::new(7, 4);
        for i in 0..300 {
            let t = i as f64 * 2.0;
            plan.select_lane(i % 3);
            let a = plan.transmit(t, 1.5, &mut rng_a);
            let b = plain.transmit(t, 1.5, &mut rng_b);
            assert_eq!(a, b, "packet {i} diverged");
        }
        // the RNG streams themselves must stay in lockstep
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    fn outage_defers_the_send_and_burns_attempts_without_rng() {
        let spec = FaultSpec::parse("outage:10:6").unwrap();
        let mut plan = FaultPlan::new(spec, IdealChannel);
        let mut rng = Pcg32::seeded(1);
        let before = rng.clone();
        // before the window: untouched
        let d = plan.transmit(0.0, 2.0, &mut rng);
        assert_eq!((d.arrival, d.attempts), (2.0, 1));
        // inside the window at t=11 with duration 2: attempts at 11, 13,
        // 15 all start inside [10,16) and fail; the 4th at 17 succeeds
        let d = plan.transmit(11.0, 2.0, &mut rng);
        assert_eq!(d.attempts, 4);
        assert_eq!(d.arrival, 19.0);
        // past the one-shot window: untouched again
        let d = plan.transmit(20.0, 2.0, &mut rng);
        assert_eq!((d.arrival, d.attempts), (22.0, 1));
        // an ideal inner channel + scripted faults never draw randomness
        let mut untouched = before;
        assert_eq!(rng.next_u64(), untouched.next_u64());
    }

    #[test]
    fn periodic_outage_refires() {
        let spec = FaultSpec::parse("outage:0:1:10").unwrap();
        let mut plan = FaultPlan::new(spec, IdealChannel);
        let mut rng = Pcg32::seeded(2);
        for k in 0..5 {
            let t = 10.0 * k as f64 + 0.5; // inside the k-th occurrence
            let d = plan.transmit(t, 2.0, &mut rng);
            assert_eq!(d.attempts, 2, "occurrence {k}");
            assert_eq!(d.arrival, t + 2.0 * 2.0, "occurrence {k}");
        }
    }

    #[test]
    fn dropped_lane_never_delivers_and_others_are_unaffected() {
        let spec = FaultSpec::parse("drop:1:100").unwrap();
        let mut plan = FaultPlan::new(spec, IdealChannel);
        let mut rng = Pcg32::seeded(3);
        plan.select_lane(1);
        assert_eq!(plan.transmit(50.0, 2.0, &mut rng).arrival, 52.0);
        assert_eq!(plan.transmit(100.0, 2.0, &mut rng).arrival, f64::INFINITY);
        assert_eq!(plan.transmit(500.0, 2.0, &mut rng).arrival, f64::INFINITY);
        plan.select_lane(0);
        assert_eq!(plan.transmit(500.0, 2.0, &mut rng).arrival, 502.0);
        // the pinned-lane form used inside MultiLaneChannel
        let spec = FaultSpec::parse("drop:2:0").unwrap();
        let mut pinned = FaultPlan::new(spec, IdealChannel).for_lane(2);
        assert_eq!(pinned.transmit(0.0, 1.0, &mut rng).arrival, f64::INFINITY);
    }

    #[test]
    fn ackloss_retransmits_whole_blocks() {
        // p = 0.999…: first draws will almost surely force retransmits;
        // use a deterministic check instead: p=0 never draws, and with
        // p>0 the arrival is a multiple of the duration and attempts
        // count every retransmission
        let spec = FaultSpec::parse("ackloss:0.5").unwrap();
        let mut plan = FaultPlan::new(spec, IdealChannel);
        let mut rng = Pcg32::seeded(4);
        let mut saw_retransmit = false;
        for i in 0..200 {
            let t = 10.0 * i as f64;
            let d = plan.transmit(t, 2.0, &mut rng);
            assert!(d.attempts >= 1);
            assert_eq!(d.arrival, t + 2.0 * d.attempts as f64);
            saw_retransmit |= d.attempts > 1;
        }
        assert!(saw_retransmit, "p=0.5 never retransmitted in 200 packets");
    }

    #[test]
    fn outage_gaps_narrower_than_a_packet_give_up_deterministically() {
        // 9-wide windows with 1-wide gaps, 10-wide packets: no attempt
        // ever starts outside a window
        let spec = FaultSpec::parse("outage:0:9:10").unwrap();
        let mut plan = FaultPlan::new(spec, IdealChannel);
        let mut rng = Pcg32::seeded(5);
        let d = plan.transmit(0.0, 10.0, &mut rng);
        assert_eq!(d.arrival, f64::INFINITY);
        assert!(d.attempts >= MAX_OUTAGE_ATTEMPTS);
    }
}
