//! Gilbert–Elliott two-state fading channel (bursty wireless links).
//!
//! Real edge uplinks are not i.i.d.: losses cluster in fades. The
//! classic Gilbert–Elliott model captures this with a two-state Markov
//! chain — a *good* state and a *bad* (fade) state, each with its own
//! relative rate and per-attempt erasure probability. The chain is
//! clocked **per packet**: one transition draw at the start of every
//! [`transmit`](Channel::transmit) call, then the whole packet
//! (including its ARQ retransmissions) experiences the resulting
//! state's link parameters.
//!
//! Two invariants matter for the test harness:
//!
//! * **Degenerate chains consume no transition randomness.** The
//!   transition uniform is only drawn when the outcome is actually
//!   random (`p_flip > 0`), so a channel that can never leave the good
//!   state (`p_gb = 0`) consumes the `STREAM_CHANNEL` RNG draw-for-draw
//!   like [`ErasureChannel`] with `p_loss = p_loss_good`. With the
//!   additional precondition `rate_good = 1` (the erasure channel is
//!   unit-rate, and arrivals scale by `1/rate`), the resulting event
//!   traces are bit-identical (asserted in
//!   `rust/tests/golden_traces.rs`).
//! * **ARQ semantics match [`ErasureChannel`] exactly** (one uniform per
//!   attempt, same 1000-attempt cap), so the erasure channel is the
//!   `p_gb = 0` special case, not a separate code path to keep in sync.

use crate::util::rng::Pcg32;

use super::{Channel, Delivery};

/// One state's link parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkState {
    /// Relative rate (1.0 = the paper's one-sample-per-unit link).
    pub rate: f64,
    /// Per-attempt erasure probability in [0, 1).
    pub p_loss: f64,
}

impl LinkState {
    pub fn new(rate: f64, p_loss: f64) -> LinkState {
        assert!(rate > 0.0, "state rate must be positive, got {rate}");
        assert!(
            (0.0..1.0).contains(&p_loss),
            "state p_loss must be in [0,1), got {p_loss}"
        );
        LinkState { rate, p_loss }
    }

    /// Expected channel occupancy per unit of nominal duration in this
    /// state: E[attempts]/rate = 1/((1−p)·rate).
    pub fn expected_slowdown(&self) -> f64 {
        1.0 / ((1.0 - self.p_loss) * self.rate)
    }
}

/// Stationary P(bad) of a two-state chain with per-packet transition
/// probabilities `p_gb`/`p_bg` — THE degenerate-chain convention
/// (`p_gb ≤ 0` pins good → 0; `p_bg ≤ 0` with `p_gb > 0` makes bad
/// absorbing → 1), shared by the channel and the belief estimator
/// (`channel::estimator::GeParams`) so the two can never drift apart.
pub fn stationary_p_bad(p_gb: f64, p_bg: f64) -> f64 {
    if p_gb <= 0.0 {
        0.0
    } else if p_bg <= 0.0 {
        1.0
    } else {
        p_gb / (p_gb + p_bg)
    }
}

/// Gilbert–Elliott channel: good/bad [`LinkState`]s, per-packet Markov
/// transitions, stop-and-wait ARQ within each packet.
#[derive(Clone, Copy, Debug)]
pub struct GilbertElliottChannel {
    /// P(good → bad), sampled once per packet while in the good state.
    pub p_gb: f64,
    /// P(bad → good), sampled once per packet while in the bad state.
    pub p_bg: f64,
    /// Link parameters while the channel is good.
    pub good: LinkState,
    /// Link parameters while the channel is in a fade.
    pub bad: LinkState,
    /// Cap on ARQ attempts (same guard as [`ErasureChannel`]; 0 = ∞).
    pub max_attempts: u32,
    /// Current state (packets start in `good` for a fresh channel).
    in_bad: bool,
}

impl GilbertElliottChannel {
    /// Build a channel starting in the good state.
    pub fn new(
        p_gb: f64,
        p_bg: f64,
        good: LinkState,
        bad: LinkState,
    ) -> GilbertElliottChannel {
        assert!(
            (0.0..=1.0).contains(&p_gb) && (0.0..=1.0).contains(&p_bg),
            "transition probabilities must be in [0,1], got ({p_gb},{p_bg})"
        );
        GilbertElliottChannel {
            p_gb,
            p_bg,
            good,
            bad,
            max_attempts: 1000,
            in_bad: false,
        }
    }

    /// Stationary probability of the bad state. `p_gb = 0` pins the
    /// chain to good (0); `p_bg = 0` with `p_gb > 0` makes bad
    /// absorbing (1).
    pub fn stationary_p_bad(&self) -> f64 {
        stationary_p_bad(self.p_gb, self.p_bg)
    }

    /// Expected long-run slowdown factor: the stationary mixture of the
    /// per-state occupancies. (Approximation: within one packet, ARQ
    /// attempts share the packet's state; across packets the mixture is
    /// exact in the stationary regime.)
    pub fn expected_slowdown(&self) -> f64 {
        let pb = self.stationary_p_bad();
        (1.0 - pb) * self.good.expected_slowdown()
            + pb * self.bad.expected_slowdown()
    }

    /// Whether the channel is currently in a fade (test hook).
    pub fn is_bad(&self) -> bool {
        self.in_bad
    }
}

impl Channel for GilbertElliottChannel {
    fn transmit(
        &mut self,
        sent_at: f64,
        duration: f64,
        rng: &mut Pcg32,
    ) -> Delivery {
        // per-packet Markov step; the draw is skipped when the outcome
        // is deterministic so degenerate chains stay stream-identical
        // to ErasureChannel
        let p_flip = if self.in_bad { self.p_bg } else { self.p_gb };
        if p_flip >= 1.0 || (p_flip > 0.0 && rng.next_f64() < p_flip) {
            self.in_bad = !self.in_bad;
        }
        let state = if self.in_bad { self.bad } else { self.good };
        // ARQ loop identical to ErasureChannel::transmit
        let mut attempts = 1u32;
        while rng.next_f64() < state.p_loss {
            if self.max_attempts > 0 && attempts >= self.max_attempts {
                break;
            }
            attempts += 1;
        }
        Delivery {
            arrival: sent_at + attempts as f64 * duration / state.rate,
            attempts,
        }
    }

    fn describe(&self) -> String {
        format!(
            "gilbert-elliott (p_gb={}, p_bg={}, good=({}, p={}), \
             bad=({}, p={}), ARQ)",
            self.p_gb,
            self.p_bg,
            self.good.rate,
            self.good.p_loss,
            self.bad.rate,
            self.bad.p_loss
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::ErasureChannel;

    fn bursty() -> GilbertElliottChannel {
        GilbertElliottChannel::new(
            0.2,
            0.5,
            LinkState::new(1.0, 0.05),
            LinkState::new(0.5, 0.6),
        )
    }

    #[test]
    fn pinned_good_state_matches_erasure_stream_exactly() {
        // p_gb = 0: no transition draws, so the fading channel must be
        // draw-for-draw identical to ErasureChannel at the good p_loss
        let p = 0.3;
        let mut ge = GilbertElliottChannel::new(
            0.0,
            0.7,
            LinkState::new(1.0, p),
            LinkState::new(0.25, 0.9),
        );
        let mut er = ErasureChannel::new(p);
        let mut rng_a = Pcg32::new(42, 4);
        let mut rng_b = Pcg32::new(42, 4);
        for i in 0..200 {
            let t = i as f64 * 3.0;
            let a = ge.transmit(t, 2.5, &mut rng_a);
            let b = er.transmit(t, 2.5, &mut rng_b);
            assert_eq!(a, b, "packet {i} diverged");
        }
        assert!(!ge.is_bad());
    }

    #[test]
    fn deterministic_flip_probabilities_need_no_draw() {
        // p_gb = 1, p_bg = 1: alternates every packet without consuming
        // transition randomness (loss-free states: no ARQ randomness
        // is consumed beyond the one per-attempt uniform each)
        let mut ge = GilbertElliottChannel::new(
            1.0,
            1.0,
            LinkState::new(1.0, 0.0),
            LinkState::new(0.5, 0.0),
        );
        let mut rng = Pcg32::seeded(9);
        let a = ge.transmit(0.0, 2.0, &mut rng);
        assert!(ge.is_bad(), "first packet flips good -> bad");
        assert_eq!(a.arrival, 4.0, "bad state halves the rate");
        let b = ge.transmit(4.0, 2.0, &mut rng);
        assert!(!ge.is_bad(), "second packet flips back");
        assert_eq!(b.arrival, 6.0);
    }

    #[test]
    fn bad_state_is_slower_on_average() {
        let mut ge = bursty();
        let mut rng = Pcg32::seeded(5);
        let trials = 20_000;
        let mut occupancy = 0.0;
        for _ in 0..trials {
            let d = ge.transmit(0.0, 1.0, &mut rng);
            occupancy += d.arrival;
        }
        let mean = occupancy / trials as f64;
        let want = ge.expected_slowdown();
        // stationary mixture of 1/((1-p)·rate); generous tolerance for
        // the per-packet (not per-attempt) state clocking
        assert!(
            (mean - want).abs() < 0.1 * want,
            "mean occupancy {mean} vs stationary estimate {want}"
        );
        assert!(mean > 1.0, "fades must slow the link down");
    }

    #[test]
    fn stationary_probability_edge_cases() {
        let g = LinkState::new(1.0, 0.0);
        let b = LinkState::new(1.0, 0.5);
        assert_eq!(
            GilbertElliottChannel::new(0.0, 0.5, g, b).stationary_p_bad(),
            0.0
        );
        assert_eq!(
            GilbertElliottChannel::new(0.5, 0.0, g, b).stationary_p_bad(),
            1.0
        );
        let pi = GilbertElliottChannel::new(0.1, 0.3, g, b).stationary_p_bad();
        assert!((pi - 0.25).abs() < 1e-12);
    }

    #[test]
    fn arrivals_are_monotone_in_send_time() {
        let mut ge = bursty();
        let mut rng = Pcg32::seeded(77);
        let mut t = 0.0;
        for _ in 0..500 {
            let d = ge.transmit(t, 4.0, &mut rng);
            assert!(d.arrival > t, "arrival must follow the send time");
            t = d.arrival;
        }
    }

    #[test]
    #[should_panic]
    fn bad_rate_rejected() {
        LinkState::new(0.0, 0.1);
    }

    #[test]
    #[should_panic]
    fn bad_transition_probability_rejected() {
        GilbertElliottChannel::new(
            1.5,
            0.5,
            LinkState::new(1.0, 0.0),
            LinkState::new(1.0, 0.0),
        );
    }
}
