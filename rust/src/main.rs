//! `edgepipe` — the Layer-3 leader binary.
//!
//! Parses the command line, loads/merges configuration, and dispatches to
//! the subcommands in [`edgepipe::cli::commands`]. See `edgepipe help`.

use edgepipe::cli::{dispatch, Args};
use edgepipe::util::alloc::CountingAllocator;

// Counting allocator so `edgepipe bench` can report allocations-per-run.
// Cost for every other subcommand: one relaxed fetch_add per
// alloc/realloc — noise next to malloc itself, and the sweep hot path
// this binary cares about allocates ~nothing after warm-up. Revisit with
// per-thread counters if a profile ever shows the shared cache line.
#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() {
    edgepipe::util::alloc::mark_installed();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\nrun `edgepipe help` for usage");
            std::process::exit(2);
        }
    };
    match dispatch(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
