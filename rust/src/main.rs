//! `edgepipe` — the Layer-3 leader binary.
//!
//! Parses the command line, loads/merges configuration, and dispatches to
//! the subcommands in [`edgepipe::cli::commands`]. See `edgepipe help`.

use edgepipe::cli::{dispatch, Args};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\nrun `edgepipe help` for usage");
            std::process::exit(2);
        }
    };
    match dispatch(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
