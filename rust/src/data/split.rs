//! Deterministic train/eval splitting (paper Sec. 5: a random 90% of
//! California Housing forms the training set X, N = 18 576).

use crate::util::rng::Pcg32;

use super::dataset::Dataset;

/// Split `ds` into (train, eval) with `train_frac` of the samples in the
/// training set, shuffled deterministically by `seed`.
pub fn train_split(
    ds: &Dataset,
    train_frac: f64,
    seed: u64,
) -> (Dataset, Dataset) {
    assert!((0.0..=1.0).contains(&train_frac), "bad fraction");
    let mut idx: Vec<usize> = (0..ds.n).collect();
    let mut rng = Pcg32::new(seed, 202);
    rng.shuffle(&mut idx);
    let n_train = (ds.n as f64 * train_frac).round() as usize;
    let (train_idx, eval_idx) = idx.split_at(n_train);
    (ds.subset(train_idx), ds.subset(eval_idx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};

    #[test]
    fn sizes_match_paper_convention() {
        let ds = synth_calhousing(&SynthSpec { n: 20640, ..Default::default() });
        let (train, eval) = train_split(&ds, 0.9, 42);
        assert_eq!(train.n, 18576); // the paper's N
        assert_eq!(eval.n, 20640 - 18576);
        assert_eq!(train.d, 8);
    }

    #[test]
    fn deterministic_and_disjoint() {
        let ds = synth_calhousing(&SynthSpec { n: 200, ..Default::default() });
        let (t1, e1) = train_split(&ds, 0.8, 7);
        let (t2, _) = train_split(&ds, 0.8, 7);
        assert_eq!(t1.x, t2.x);
        // all eval samples differ from all train samples (rows unique whp)
        for i in 0..e1.n {
            for j in 0..t1.n {
                assert_ne!(e1.row(i), t1.row(j));
            }
        }
    }

    #[test]
    fn different_seed_different_split() {
        let ds = synth_calhousing(&SynthSpec { n: 200, ..Default::default() });
        let (t1, _) = train_split(&ds, 0.8, 1);
        let (t2, _) = train_split(&ds, 0.8, 2);
        assert_ne!(t1.x, t2.x);
    }
}
