//! Synthetic California-Housing-like dataset (DESIGN.md §3 substitution).
//!
//! The paper's experiments (Sec. 5) use ridge regression on California
//! Housing (20 640 × 8) and report the constants `L = 1.908`, `c = 0.061`
//! — the extreme eigenvalues of the loss Hessian the Corollary-1 bound
//! consumes. The real CSV is not redistributable in this offline image, so
//! we synthesize a dataset that is *exact where the analysis looks*:
//!
//! 1. draw `Z ∈ R^{n×d}` i.i.d. standard normal;
//! 2. compute the empirical Gram `G = ZᵀZ/n` and whiten: `Z G^{-1/2}` has
//!    Gram exactly `I`;
//! 3. re-color with a target SPD matrix `S^{1/2}` whose spectrum is chosen
//!    log-spaced so the empirical loss Hessian `H = 2·(XᵀX/n)` has extreme
//!    eigenvalues exactly `(c, L) = (0.061, 1.908)`;
//! 4. labels `y = X w° + σ ε` from a fixed ground-truth `w°`.
//!
//! The resulting dataset matches the paper's `(N, d, L, c)` exactly (up to
//! f32 rounding ~1e-6), which is everything the bound and the bias/variance
//! trade-off in Figs. 3–4 depend on. If you have the real CSV, pass
//! `--data path.csv` instead (see `data::csv`).

use crate::linalg::sym_eig::{spd_inv_sqrt, spd_sqrt};
use crate::linalg::Mat;

#[cfg(test)]
use crate::linalg::{gram_matrix, sym_eig::jacobi_eigen};
use crate::util::rng::Pcg32;

use super::dataset::Dataset;

/// Parameters of the synthetic generator.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// Number of samples (paper: 20 640 raw, 18 576 after the 90% split).
    pub n: usize,
    /// Feature dimension (paper: 8).
    pub d: usize,
    /// Largest eigenvalue of the loss Hessian `2G` (paper: L = 1.908).
    pub hess_max: f64,
    /// Smallest eigenvalue of the loss Hessian `2G` (paper: c = 0.061).
    pub hess_min: f64,
    /// Label noise standard deviation.
    pub noise_std: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            n: 20640,
            d: 8,
            hess_max: 1.908,
            hess_min: 0.061,
            noise_std: 0.5,
            seed: 1906_04488, // the paper's arXiv id
        }
    }
}

/// Generate the synthetic dataset described in the module docs.
pub fn synth_calhousing(spec: &SynthSpec) -> Dataset {
    let (n, d) = (spec.n, spec.d);
    assert!(n > d, "need n > d for whitening");
    let mut rng = Pcg32::new(spec.seed, 101);

    // 1. raw gaussians, f64 during construction for exact whitening
    let mut z = vec![0.0f64; n * d];
    for v in z.iter_mut() {
        *v = rng.next_gaussian();
    }

    // 2. empirical Gram of Z and its inverse square root
    let z32: Vec<f32> = z.iter().map(|&v| v as f32).collect();
    let g = gram_matrix_f64(&z, n, d);
    drop(z32);
    let g_inv_sqrt = spd_inv_sqrt(&g);

    // 3. target spectrum for the Hessian H = 2 * Gram(X): log-spaced
    //    between hess_min and hess_max -> Gram spectrum = H/2.
    let spectrum = log_spaced(spec.hess_min / 2.0, spec.hess_max / 2.0, d);
    // random orthogonal basis for the target Gram
    let q = random_orthogonal(d, &mut rng);
    let s_target =
        q.matmul(&Mat::diag(&spectrum)).matmul(&q.transpose());
    let s_sqrt = spd_sqrt(&s_target);
    // combined transform M = G^{-1/2} S^{1/2}: Gram(Z M) = S exactly
    let m = g_inv_sqrt.matmul(&s_sqrt);

    // apply transform row-by-row
    let mut x = vec![0.0f32; n * d];
    for i in 0..n {
        let zrow = &z[i * d..(i + 1) * d];
        for j in 0..d {
            let mut acc = 0.0;
            for k in 0..d {
                acc += zrow[k] * m[(k, j)];
            }
            x[i * d + j] = acc as f32;
        }
    }

    // 4. labels from a fixed ground-truth direction + noise
    let w_true = ground_truth_w(d);
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        let mut dot = 0.0;
        for j in 0..d {
            dot += row[j] as f64 * w_true[j];
        }
        y[i] = (dot + spec.noise_std * rng.next_gaussian()) as f32;
    }

    Dataset::new(x, y, n, d)
}

/// The fixed ground-truth parameter used for label synthesis.
pub fn ground_truth_w(d: usize) -> Vec<f64> {
    // deterministic, O(1)-describable, non-axis-aligned direction
    let mut w: Vec<f64> =
        (0..d).map(|j| ((j as f64) * 0.7 + 0.3).sin() + 0.5).collect();
    let norm = w.iter().map(|v| v * v).sum::<f64>().sqrt();
    for v in w.iter_mut() {
        *v *= 1.5 / norm;
    }
    w
}

/// `count` log-spaced values from `lo` to `hi` inclusive (ascending).
pub fn log_spaced(lo: f64, hi: f64, count: usize) -> Vec<f64> {
    assert!(lo > 0.0 && hi > lo && count >= 2);
    let (llo, lhi) = (lo.ln(), hi.ln());
    (0..count)
        .map(|i| {
            let t = i as f64 / (count - 1) as f64;
            (llo + t * (lhi - llo)).exp()
        })
        .collect()
}

/// Random orthogonal matrix via Gram-Schmidt on a Gaussian matrix.
fn random_orthogonal(d: usize, rng: &mut Pcg32) -> Mat {
    let mut q = Mat::zeros(d, d);
    for col in 0..d {
        // draw a random column
        let mut v: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        // orthogonalize against previous columns (twice, for stability)
        for _ in 0..2 {
            for prev in 0..col {
                let dot: f64 =
                    (0..d).map(|r| v[r] * q[(r, prev)]).sum();
                for r in 0..d {
                    v[r] -= dot * q[(r, prev)];
                }
            }
        }
        let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(norm > 1e-12, "degenerate direction in Gram-Schmidt");
        for r in 0..d {
            q[(r, col)] = v[r] / norm;
        }
    }
    q
}

/// f64 Gram used during construction (higher precision than data::gram).
fn gram_matrix_f64(x: &[f64], n: usize, d: usize) -> Mat {
    let mut g = Mat::zeros(d, d);
    for row in x.chunks_exact(d) {
        for i in 0..d {
            for j in i..d {
                g[(i, j)] += row[i] * row[j];
            }
        }
    }
    for i in 0..d {
        for j in i..d {
            let v = g[(i, j)] / n as f64;
            g[(i, j)] = v;
            g[(j, i)] = v;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectrum_matches_paper_constants() {
        let spec = SynthSpec { n: 4000, ..Default::default() };
        let ds = synth_calhousing(&spec);
        let g = gram_matrix(&ds.x, ds.n, ds.d);
        let eig = jacobi_eigen(&g);
        let hess_min = 2.0 * eig.values[0];
        let hess_max = 2.0 * eig.values[ds.d - 1];
        // f32 storage rounds the exact construction slightly
        assert!(
            (hess_max - 1.908).abs() < 1e-3,
            "L = {hess_max}, want 1.908"
        );
        assert!(
            (hess_min - 0.061).abs() < 1e-3,
            "c = {hess_min}, want 0.061"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = SynthSpec { n: 500, ..Default::default() };
        let a = synth_calhousing(&spec);
        let b = synth_calhousing(&spec);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = synth_calhousing(&SynthSpec { seed: 7, ..spec });
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn labels_correlate_with_ground_truth() {
        let spec = SynthSpec { n: 2000, noise_std: 0.1, ..Default::default() };
        let ds = synth_calhousing(&spec);
        let w = ground_truth_w(ds.d);
        // residual power must be close to noise power
        let mut resid = 0.0;
        let mut total = 0.0;
        for i in 0..ds.n {
            let row = ds.row(i);
            let pred: f64 =
                (0..ds.d).map(|j| row[j] as f64 * w[j]).sum();
            resid += (ds.y[i] as f64 - pred).powi(2);
            total += (ds.y[i] as f64).powi(2);
        }
        resid /= ds.n as f64;
        total /= ds.n as f64;
        assert!((resid - 0.01).abs() < 0.005, "resid={resid}");
        assert!(total > 5.0 * resid, "labels mostly signal");
    }

    #[test]
    fn log_spaced_endpoints() {
        let v = log_spaced(0.1, 10.0, 5);
        assert!((v[0] - 0.1).abs() < 1e-12);
        assert!((v[4] - 10.0).abs() < 1e-9);
        for w in v.windows(2) {
            assert!(w[1] > w[0]);
        }
    }
}
