//! CSV load/save for datasets (drop-in for the real California Housing).
//!
//! Format: one sample per line, `d` covariate columns then the label, with
//! an optional header line (auto-detected: a first line that fails to
//! parse as numbers is skipped).

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::dataset::Dataset;

/// Load a dataset from a CSV file; the last column is the label.
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let reader = BufReader::new(file);
    let mut x: Vec<f32> = Vec::new();
    let mut y: Vec<f32> = Vec::new();
    let mut d: Option<usize> = None;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let fields: Result<Vec<f32>, _> =
            trimmed.split(',').map(|f| f.trim().parse::<f32>()).collect();
        let fields = match fields {
            Ok(f) => f,
            Err(_) if lineno == 0 => continue, // header line
            Err(e) => bail!("line {}: {e}", lineno + 1),
        };
        if fields.len() < 2 {
            bail!("line {}: need >= 2 columns", lineno + 1);
        }
        let cols = fields.len() - 1;
        match d {
            None => d = Some(cols),
            Some(dd) if dd != cols => {
                bail!("line {}: {cols} covariates, expected {dd}", lineno + 1)
            }
            _ => {}
        }
        x.extend_from_slice(&fields[..cols]);
        y.push(fields[cols]);
    }
    let d = d.context("empty CSV")?;
    let n = y.len();
    Ok(Dataset::new(x, y, n, d))
}

/// Save a dataset to CSV (covariates then label per row).
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    let mut file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    for i in 0..ds.n {
        let mut line = String::new();
        for v in ds.row(i) {
            line.push_str(&format!("{v},"));
        }
        line.push_str(&format!("{}\n", ds.y[i]));
        file.write_all(line.as_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ds = Dataset::new(
            vec![1.5, -2.0, 0.25, 3.0],
            vec![0.5, -1.0],
            2,
            2,
        );
        let dir = std::env::temp_dir().join("edgepipe_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.csv");
        save_csv(&ds, &path).unwrap();
        let back = load_csv(&path).unwrap();
        assert_eq!(back.x, ds.x);
        assert_eq!(back.y, ds.y);
        assert_eq!((back.n, back.d), (2, 2));
    }

    #[test]
    fn header_and_comments_skipped() {
        let dir = std::env::temp_dir().join("edgepipe_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("header.csv");
        std::fs::write(&path, "a,b,label\n# comment\n1,2,3\n4,5,6\n").unwrap();
        let ds = load_csv(&path).unwrap();
        assert_eq!(ds.n, 2);
        assert_eq!(ds.d, 2);
        assert_eq!(ds.y, vec![3.0, 6.0]);
    }

    #[test]
    fn ragged_rows_rejected() {
        let dir = std::env::temp_dir().join("edgepipe_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ragged.csv");
        std::fs::write(&path, "1,2,3\n4,5\n").unwrap();
        assert!(load_csv(&path).is_err());
    }
}
