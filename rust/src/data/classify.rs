//! Synthetic labeled data for the logistic-classification workload.
//!
//! Two deterministic sources of `{0, 1}`-labeled datasets:
//!
//! * [`synth_logistic`] — i.i.d. Gaussian covariates with labels from a
//!   fixed linear separator (`ground_truth_w`) plus controllable margin
//!   noise and label flips. With small noise/flip rates the data is
//!   *near-separable*, which is what the metamorphic
//!   "logistic tracks ridge sign decisions" test in
//!   `rust/tests/golden_traces.rs` relies on.
//! * [`binarize_labels`] — derive a classification view of an existing
//!   regression dataset by thresholding labels at their median (the
//!   standard above/below-median-house-value task on California
//!   Housing). Covariates are shared, so channel/policy axes stay
//!   comparable across workloads; this is what `ScenarioRunner` uses
//!   when a scenario selects the logistic workload.

use crate::util::rng::Pcg32;

use super::dataset::Dataset;
use super::synth::ground_truth_w;

/// Parameters of the synthetic classification generator.
#[derive(Clone, Debug)]
pub struct LogitSpec {
    /// Number of samples.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
    /// Std of the Gaussian noise added to the margin before
    /// thresholding (0 = exactly linearly separable).
    pub margin_noise: f64,
    /// Probability of flipping each label after thresholding.
    pub flip_prob: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for LogitSpec {
    fn default() -> Self {
        LogitSpec {
            n: 20640,
            d: 8,
            margin_noise: 0.1,
            flip_prob: 0.02,
            seed: 1906_04488,
        }
    }
}

/// Generate i.i.d. standard-normal covariates with labels
/// `y_i = 1[w°ᵀx_i + margin_noise·ε_i > 0]`, each flipped with
/// probability `flip_prob` (`w°` is [`ground_truth_w`], the same
/// direction the regression generator uses).
pub fn synth_logistic(spec: &LogitSpec) -> Dataset {
    assert!(spec.n > 0 && spec.d > 0, "need a non-empty dataset");
    assert!(
        (0.0..=0.5).contains(&spec.flip_prob),
        "flip_prob must be in [0, 0.5], got {}",
        spec.flip_prob
    );
    assert!(spec.margin_noise >= 0.0, "margin_noise must be >= 0");
    let (n, d) = (spec.n, spec.d);
    let mut rng = Pcg32::new(spec.seed, 202);
    let w_true = ground_truth_w(d);

    let mut x = vec![0.0f32; n * d];
    for v in x.iter_mut() {
        *v = rng.next_gaussian() as f32;
    }
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        let mut margin = 0.0f64;
        for j in 0..d {
            margin += row[j] as f64 * w_true[j];
        }
        margin += spec.margin_noise * rng.next_gaussian();
        let mut label = if margin > 0.0 { 1.0f32 } else { 0.0f32 };
        if spec.flip_prob > 0.0 && rng.next_f64() < spec.flip_prob {
            label = 1.0 - label;
        }
        y[i] = label;
    }
    Dataset::new(x, y, n, d)
}

/// Classification view of a regression dataset: covariates shared
/// verbatim, labels replaced by `1[y_i > median(y)]`. Deterministic
/// (the median is the lower-middle order statistic, so exactly-equal
/// labels land in class 0).
pub fn binarize_labels(ds: &Dataset) -> Dataset {
    assert!(ds.n > 0, "cannot binarize an empty dataset");
    let mut sorted = ds.y.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN label"));
    let median = sorted[(ds.n - 1) / 2];
    let y = ds
        .y
        .iter()
        .map(|&v| if v > median { 1.0f32 } else { 0.0f32 })
        .collect();
    Dataset::new(ds.x.clone(), y, ds.n, ds.d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let spec = LogitSpec { n: 400, ..Default::default() };
        let a = synth_logistic(&spec);
        let b = synth_logistic(&spec);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = synth_logistic(&LogitSpec { seed: 7, ..spec });
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn labels_are_binary_and_balanced() {
        let ds = synth_logistic(&LogitSpec {
            n: 4000,
            ..Default::default()
        });
        let ones = ds.y.iter().filter(|&&v| v == 1.0).count();
        assert!(ds.y.iter().all(|&v| v == 0.0 || v == 1.0));
        // symmetric separator through the origin -> roughly balanced
        let frac = ones as f64 / ds.n as f64;
        assert!((0.4..0.6).contains(&frac), "class balance {frac}");
    }

    #[test]
    fn near_separable_labels_match_separator_sign() {
        let ds = synth_logistic(&LogitSpec {
            n: 2000,
            margin_noise: 0.0,
            flip_prob: 0.0,
            ..Default::default()
        });
        let w = ground_truth_w(ds.d);
        for i in 0..ds.n {
            let row = ds.row(i);
            let margin: f64 =
                (0..ds.d).map(|j| row[j] as f64 * w[j]).sum();
            let want = if margin > 0.0 { 1.0 } else { 0.0 };
            assert_eq!(ds.y[i], want as f32, "sample {i}");
        }
    }

    #[test]
    fn binarize_thresholds_at_the_median() {
        let ds = Dataset::new(
            vec![0.0; 5 * 2],
            vec![5.0, 1.0, 3.0, 2.0, 4.0],
            5,
            2,
        );
        let bin = binarize_labels(&ds);
        // median = 3.0; strictly-above -> class 1
        assert_eq!(bin.y, vec![1.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(bin.x, ds.x);
        // idempotent shape/determinism
        let again = binarize_labels(&ds);
        assert_eq!(bin.y, again.y);
    }
}
