//! The in-memory training set `X = {x_1..x_N}` with labels.
//!
//! Stored flat row-major (`n × d` f32, matching the AOT artifact layout)
//! so the device can transmit contiguous rows and kernels can gather
//! straight from contiguous memory.

/// A labelled dataset with flat row-major covariates.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Covariates, row-major, length `n * d`.
    pub x: Vec<f32>,
    /// Labels, length `n`.
    pub y: Vec<f32>,
    /// Number of samples.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
}

impl Dataset {
    /// Build from parts, validating shapes.
    pub fn new(x: Vec<f32>, y: Vec<f32>, n: usize, d: usize) -> Dataset {
        assert_eq!(x.len(), n * d, "covariate length mismatch");
        assert_eq!(y.len(), n, "label length mismatch");
        Dataset { x, y, n, d }
    }

    /// Borrow sample `i`'s covariates.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Label of sample `i`.
    #[inline]
    pub fn label(&self, i: usize) -> f32 {
        self.y[i]
    }

    /// Copy a subset of rows (by index) into a new dataset.
    pub fn subset(&self, indices: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(indices.len() * self.d);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset::new(x, y, indices.len(), self.d)
    }

    /// Empirical ridge loss `(1/n) Σ (wᵀx−y)² + reg‖w‖²` in f64
    /// (reg = λ/N with N the FULL dataset size; pass it explicitly).
    /// Evaluated by the batched multi-accumulator kernel
    /// (`linalg::kernels::batch_ridge_loss`), which specializes the
    /// paper's d == 8 workload — every final-loss evaluation in every
    /// sweep lands here.
    pub fn ridge_loss(&self, w: &[f64], reg: f64) -> f64 {
        assert_eq!(w.len(), self.d);
        crate::linalg::kernels::batch_ridge_loss(
            &self.x, &self.y, self.d, w, reg,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
            vec![1.0, 2.0, 3.0],
            3,
            2,
        )
    }

    #[test]
    fn rows_and_labels() {
        let ds = tiny();
        assert_eq!(ds.row(0), &[1.0, 0.0]);
        assert_eq!(ds.row(2), &[1.0, 1.0]);
        assert_eq!(ds.label(1), 2.0);
    }

    #[test]
    fn subset_copies_rows() {
        let ds = tiny();
        let sub = ds.subset(&[2, 0]);
        assert_eq!(sub.n, 2);
        assert_eq!(sub.row(0), &[1.0, 1.0]);
        assert_eq!(sub.y, vec![3.0, 1.0]);
    }

    #[test]
    fn ridge_loss_known_value() {
        let ds = tiny();
        // w = [1, 1]: errors = (1-1), (1-2), (2-3) -> 0,1,1; mean = 2/3
        let loss = ds.ridge_loss(&[1.0, 1.0], 0.5);
        assert!((loss - (2.0 / 3.0 + 0.5 * 2.0)).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Dataset::new(vec![1.0; 5], vec![1.0; 2], 2, 2);
    }
}
