//! Dataset substrate: the in-memory sample container, the synthetic
//! California-Housing-like generator (DESIGN.md §3 substitution), the
//! labeled classification generator for the logistic workload, CSV
//! load/save for dropping in the real dataset, train/eval splitting,
//! and multi-device sharding (IID round-robin and non-IID label skew).

pub mod classify;
pub mod csv;
pub mod dataset;
pub mod shard;
pub mod split;
pub mod synth;

pub use classify::{binarize_labels, synth_logistic, LogitSpec};
pub use dataset::Dataset;
pub use shard::{shard_label_skew, shard_round_robin};
pub use synth::{synth_calhousing, SynthSpec};
