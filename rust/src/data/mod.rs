//! Dataset substrate: the in-memory sample container, the synthetic
//! California-Housing-like generator (DESIGN.md §3 substitution), the
//! labeled classification generator for the logistic workload, CSV
//! load/save for dropping in the real dataset, and train/eval splitting.

pub mod classify;
pub mod csv;
pub mod dataset;
pub mod split;
pub mod synth;

pub use classify::{binarize_labels, synth_logistic, LogitSpec};
pub use dataset::Dataset;
pub use synth::{synth_calhousing, SynthSpec};
