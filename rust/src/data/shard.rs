//! Deterministic dataset sharding for multi-device scenarios.
//!
//! Two disjoint-cover partitions of a dataset into `k` device shards:
//!
//! * [`shard_round_robin`] — the IID layout (shard `s` holds dataset
//!   rows `s, s+k, s+2k, …`), the historical
//!   `extensions::multi_device::shard_dataset` semantics.
//! * [`shard_label_skew`] — a non-IID label-skew layout: each shard
//!   claims a `skew` fraction of its quota from its own contiguous
//!   "home" range of the label-sorted order (device 0 gets the lowest
//!   labels, device `k-1` the highest), and the rest is dealt evenly
//!   across the whole label range. `skew = 1` gives fully sorted
//!   contiguous shards; `skew = 0` spreads every shard evenly over the
//!   label distribution. For the logistic workload (binary labels) this
//!   is the classic per-device class imbalance of federated-learning
//!   benchmarks.
//!
//! Both layouts are deterministic (no RNG): the multi-device
//! determinism contract seeds only the per-device *sample draw*
//! (`STREAM_DEVICE`, seed `+1000·i`), never the shard assignment.

use super::dataset::Dataset;

/// Near-equal quota of shard `s` out of `k` for `n` rows (sizes differ
/// by at most one; earlier shards take the remainder).
fn quota(n: usize, k: usize, s: usize) -> usize {
    n / k + usize::from(s < n % k)
}

/// Shard `ds` into `k` near-equal disjoint shards, row `i` → shard
/// `i mod k` (shard `s` holds rows `s, s+k, s+2k, …` in that order).
pub fn shard_round_robin(ds: &Dataset, k: usize) -> Vec<Dataset> {
    assert!(k >= 1 && k <= ds.n, "bad shard count");
    (0..k)
        .map(|s| {
            let idx: Vec<usize> = (s..ds.n).step_by(k).collect();
            ds.subset(&idx)
        })
        .collect()
}

/// Shard `ds` into `k` near-equal disjoint shards with label skew
/// `skew ∈ [0, 1]`.
///
/// The label-sorted order is split into `k` contiguous "home" regions
/// (region `s` has shard `s`'s quota). Each shard first claims the
/// leading `round(skew · quota)` rows of its home region; every
/// unclaimed row is then dealt cyclically (in label order) to the
/// shards that still have capacity. The result is an exact partition
/// with the same near-equal sizes as [`shard_round_robin`].
pub fn shard_label_skew(ds: &Dataset, k: usize, skew: f64) -> Vec<Dataset> {
    assert!(k >= 1 && k <= ds.n, "bad shard count");
    assert!(
        (0.0..=1.0).contains(&skew),
        "skew must be in [0, 1], got {skew}"
    );
    let n = ds.n;
    // stable sort by label: ties keep dataset order, so the layout is
    // fully deterministic
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        ds.label(a)
            .partial_cmp(&ds.label(b))
            .expect("NaN label")
            .then(a.cmp(&b))
    });

    let mut shard_idx: Vec<Vec<usize>> =
        (0..k).map(|s| Vec::with_capacity(quota(n, k, s))).collect();
    let mut leftover: Vec<usize> = Vec::new();
    let mut start = 0usize;
    for (s, idx) in shard_idx.iter_mut().enumerate() {
        let q = quota(n, k, s);
        let claimed = (skew * q as f64).round() as usize; // ≤ q
        idx.extend_from_slice(&order[start..start + claimed]);
        leftover.extend_from_slice(&order[start + claimed..start + q]);
        start += q;
    }
    // deal the unclaimed rows (still in global label order) cyclically
    // to shards below quota, so every shard samples the whole range
    let mut cursor = 0usize;
    for row in leftover {
        while shard_idx[cursor % k].len() >= quota(n, k, cursor % k) {
            cursor += 1;
        }
        shard_idx[cursor % k].push(row);
        cursor += 1;
    }
    shard_idx.iter().map(|idx| ds.subset(idx)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};

    fn check_partition(ds: &Dataset, shards: &[Dataset], k: usize) {
        assert_eq!(shards.len(), k);
        let total: usize = shards.iter().map(|s| s.n).sum();
        assert_eq!(total, ds.n, "shards must cover every sample");
        for s in shards {
            assert!(
                s.n >= ds.n / k && s.n <= ds.n / k + 1,
                "shard size {} vs n/k {}",
                s.n,
                ds.n / k
            );
        }
        // exact multiset cover: every (row, label) pair accounted for
        let mut labels: Vec<f32> =
            shards.iter().flat_map(|s| s.y.iter().copied()).collect();
        let mut want: Vec<f32> = ds.y.clone();
        labels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(labels, want, "shard labels are not a permutation");
    }

    #[test]
    fn label_skew_partitions_at_every_skew() {
        let ds =
            synth_calhousing(&SynthSpec { n: 203, ..Default::default() });
        for &skew in &[0.0, 0.3, 0.5, 0.77, 1.0] {
            for k in [1usize, 2, 3, 5, 8] {
                let shards = shard_label_skew(&ds, k, skew);
                check_partition(&ds, &shards, k);
            }
        }
    }

    #[test]
    fn full_skew_gives_sorted_contiguous_shards() {
        let ds =
            synth_calhousing(&SynthSpec { n: 240, ..Default::default() });
        let shards = shard_label_skew(&ds, 4, 1.0);
        for w in shards.windows(2) {
            let max_lo = w[0].y.iter().cloned().fold(f32::MIN, f32::max);
            let min_hi = w[1].y.iter().cloned().fold(f32::MAX, f32::min);
            assert!(
                max_lo <= min_hi,
                "shard label ranges overlap: {max_lo} > {min_hi}"
            );
        }
    }

    #[test]
    fn skew_increases_label_concentration() {
        let ds =
            synth_calhousing(&SynthSpec { n: 600, ..Default::default() });
        // spread of shard label-means grows with skew
        let spread = |skew: f64| -> f64 {
            let shards = shard_label_skew(&ds, 4, skew);
            let means: Vec<f64> = shards
                .iter()
                .map(|s| {
                    s.y.iter().map(|&v| v as f64).sum::<f64>() / s.n as f64
                })
                .collect();
            let grand = means.iter().sum::<f64>() / means.len() as f64;
            means.iter().map(|m| (m - grand).powi(2)).sum::<f64>()
        };
        let (lo, mid, hi) = (spread(0.0), spread(0.5), spread(1.0));
        assert!(lo < mid && mid < hi, "spread not monotone: {lo} {mid} {hi}");
        assert!(hi > 10.0 * lo.max(1e-12), "full skew barely concentrates");
    }

    #[test]
    fn zero_skew_spreads_every_shard_over_the_range() {
        let ds =
            synth_calhousing(&SynthSpec { n: 400, ..Default::default() });
        let shards = shard_label_skew(&ds, 4, 0.0);
        let grand =
            ds.y.iter().map(|&v| v as f64).sum::<f64>() / ds.n as f64;
        for s in &shards {
            let mean = s.y.iter().map(|&v| v as f64).sum::<f64>() / s.n as f64;
            let std = {
                let var = ds
                    .y
                    .iter()
                    .map(|&v| (v as f64 - grand).powi(2))
                    .sum::<f64>()
                    / ds.n as f64;
                var.sqrt()
            };
            assert!(
                (mean - grand).abs() < 0.2 * std,
                "shard mean {mean} far from grand mean {grand}"
            );
        }
    }

    #[test]
    fn round_robin_matches_historical_layout() {
        let ds =
            synth_calhousing(&SynthSpec { n: 103, ..Default::default() });
        let shards = shard_round_robin(&ds, 4);
        check_partition(&ds, &shards, 4);
        for (s, shard) in shards.iter().enumerate() {
            for j in 0..shard.n {
                assert_eq!(shard.row(j), ds.row(s + j * 4));
            }
        }
    }

    #[test]
    #[should_panic]
    fn bad_skew_rejected() {
        let ds =
            synth_calhousing(&SynthSpec { n: 20, ..Default::default() });
        shard_label_skew(&ds, 2, 1.5);
    }
}
