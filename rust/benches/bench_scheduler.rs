//! Perf: the unified scheduler hot loop vs sweep throughput.
//!
//! The generic `run_schedule` core replaced four hand-rolled protocol
//! loops; the acceptance bar is that the unified loop is no slower than
//! the seed DES (target: faster, from reusing one `BlockFrame` instead
//! of allocating three fresh `Vec`s per transmitted block). This bench
//! reports (a) single-run throughput at paper scale across block sizes
//! — small `n_c` maximizes per-block overhead and therefore the
//! allocation win — (b) a Monte-Carlo sweep through the scenario-generic
//! runner, and (c) the multi-device and online-arrival variants that now
//! ride the same loop.
//!
//! Run: `cargo bench --bench bench_scheduler`

use edgepipe::bench::Bench;
use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::extensions::multi_device::{run_multi_device, shard_dataset};
use edgepipe::extensions::online::run_online_arrivals;
use edgepipe::model::RidgeModel;
use edgepipe::sweep::runner::mc_scenario_loss;
use edgepipe::sweep::scenario::ScenarioSpec;

fn main() {
    let mut bench = Bench::new();
    let raw = synth_calhousing(&SynthSpec::default());
    let (train, _) = train_split(&raw, 0.9, 42);
    let t = 1.5 * train.n as f64;
    let mk = |cfg: &DesConfig| {
        NativeExecutor::new(
            RidgeModel::new(train.d, cfg.lambda, train.n),
            cfg.alpha,
        )
    };

    // (a) unified hot loop, paper scale; n_c=10 is allocation-dominated
    for n_c in [10usize, 100, 1378] {
        let cfg = DesConfig {
            record_blocks: false,
            ..DesConfig::paper(n_c, 100.0, t, 7)
        };
        let updates = run_des(&train, &cfg, &mut IdealChannel, &mut mk(&cfg))
            .unwrap()
            .updates;
        bench.run(
            &format!("unified DES (n_c={n_c}, {updates} updates)"),
            updates as f64,
            || {
                let mut exec = mk(&cfg);
                std::hint::black_box(
                    run_des(&train, &cfg, &mut IdealChannel, &mut exec)
                        .unwrap()
                        .final_loss,
                );
            },
        );
    }

    // (b) Monte-Carlo sweep throughput through the scenario runner
    let sweep_cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(437, 100.0, t, 7)
    };
    let seeds = 16usize;
    bench.run(
        &format!("mc sweep, paper scenario ({seeds} seeds)"),
        seeds as f64,
        || {
            std::hint::black_box(
                mc_scenario_loss(
                    &train,
                    &sweep_cfg,
                    &ScenarioSpec::paper(),
                    seeds,
                    0,
                )
                .expect("mc sweep")
                .mean,
            );
        },
    );

    // (c) the variants that now share the loop
    let shards = shard_dataset(&train, 8);
    let multi_cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(437, 100.0, t, 7)
    };
    let multi_updates = run_multi_device(
        &train,
        &shards,
        &multi_cfg,
        &mut IdealChannel,
        &mut mk(&multi_cfg),
    )
    .unwrap()
    .updates;
    bench.run(
        &format!("multi-device k=8 ({multi_updates} updates)"),
        multi_updates as f64,
        || {
            let mut exec = mk(&multi_cfg);
            std::hint::black_box(
                run_multi_device(
                    &train,
                    &shards,
                    &multi_cfg,
                    &mut IdealChannel,
                    &mut exec,
                )
                .unwrap()
                .final_loss,
            );
        },
    );

    let online_cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(437, 100.0, t, 7)
    };
    bench.run("online arrivals (rate=2/unit)", train.n as f64, || {
        let mut exec = mk(&online_cfg);
        std::hint::black_box(
            run_online_arrivals(
                &train,
                &online_cfg,
                2.0,
                &mut IdealChannel,
                &mut exec,
            )
            .unwrap()
            .final_loss,
        );
    });
}
