//! Perf: the Monte-Carlo sweep engines measured against each other on
//! an `mc_final_loss`-style workload.
//!
//! Measures every engine shape in one process (identical `(n_c, seed)`
//! jobs, bit-identical losses asserted):
//!
//! * baseline — a pool spawn per grid point, a fresh allocation set per
//!   run (the pre-change engine shape);
//! * optimized — one flat `(n_c, seed)` fan-out with per-worker
//!   `RunWorkspace` reuse (the scalar engine);
//! * batched — the batched-seed engine (`sweep/batch.rs`) at each lane
//!   width L ∈ {4, 8, 16}: seed-groups traced once, replayed through
//!   SoA SGD kernels.
//!
//! Reports runs/sec, SGD updates/sec and allocations-per-run (this
//! binary installs the counting allocator), and writes the result to
//! `BENCH_sweep.json` (schema 2) so future PRs regress against it.
//!
//! Run: `cargo bench --bench bench_sweep`
//! (CI scale: `EDGEPIPE_BENCH_FAST=1 cargo bench --bench bench_sweep`)

use edgepipe::bench::sweep::{run_sweep_bench, SweepBenchConfig};
use edgepipe::util::alloc::{mark_installed, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() {
    mark_installed();
    let cfg = SweepBenchConfig::from_env();
    let report = run_sweep_bench(&cfg);
    print!("{}", report.render());
    let out = "BENCH_sweep.json";
    std::fs::write(out, report.to_value().to_json_pretty())
        .expect("write BENCH_sweep.json");
    println!("wrote {out}");
    // enforce the regression bars when asked (machine-dependent, so
    // opt-in: EDGEPIPE_BENCH_MIN_SPEEDUP=1.5 makes this run fail below).
    // The bar applies to BOTH tracked ratios: workspace-reuse vs the
    // pre-workspace baseline, and the widest-lane batched engine vs the
    // scalar optimized engine.
    if let Ok(min) = std::env::var("EDGEPIPE_BENCH_MIN_SPEEDUP") {
        let min: f64 = min.parse().expect("bad EDGEPIPE_BENCH_MIN_SPEEDUP");
        assert!(
            report.speedup >= min,
            "sweep engine speedup {:.2}x below the required {min}x",
            report.speedup
        );
        let widest = report
            .widest_lane_row()
            .expect("bench measured no lane widths");
        assert!(
            widest.speedup >= min,
            "batched engine (L={}) speedup {:.2}x vs scalar below the \
             required {min}x",
            widest.lanes,
            widest.speedup
        );
    }
}
