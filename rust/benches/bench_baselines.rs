//! Bench Abl-1: pipelined (paper) vs sequential (no overlap) vs
//! transmit-all-first across overheads — who wins and by how much.
//!
//! Run: `cargo bench --bench bench_baselines`

use edgepipe::baselines::{sequential, transmit_all_first};
use edgepipe::bench::Bench;
use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::RidgeModel;

fn main() {
    let mut bench = Bench::new();
    bench.run_once("baseline comparison across overheads", || {
        let raw = synth_calhousing(&SynthSpec::default());
        let (train, _) = train_split(&raw, 0.9, 42);
        let t = 1.5 * train.n as f64;
        println!(
            "{:>7} {:>7} | {:>12} {:>12} {:>12} | winner",
            "n_o", "n_c", "pipelined", "sequential", "all-first"
        );
        for n_o in [1.0, 10.0, 100.0, 1000.0] {
            for n_c in [100usize, 1378] {
                let cfg = DesConfig {
                    record_blocks: false,
                    ..DesConfig::paper(n_c, n_o, t, 7)
                };
                let mk = || {
                    NativeExecutor::new(
                        RidgeModel::new(train.d, cfg.lambda, train.n),
                        cfg.alpha,
                    )
                };
                let pipe =
                    run_des(&train, &cfg, &mut IdealChannel, &mut mk())
                        .unwrap();
                let seq =
                    sequential(&train, &cfg, &mut IdealChannel, &mut mk())
                        .unwrap();
                let all = transmit_all_first(
                    &train,
                    &cfg,
                    &mut IdealChannel,
                    &mut mk(),
                )
                .unwrap();
                let best = [
                    ("pipelined", pipe.final_loss),
                    ("sequential", seq.final_loss),
                    ("all-first", all.final_loss),
                ]
                .into_iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
                println!(
                    "{:>7} {:>7} | {:>12.6} {:>12.6} {:>12.6} | {}",
                    n_o, n_c, pipe.final_loss, seq.final_loss,
                    all.final_loss, best.0
                );
            }
        }
    });
}
