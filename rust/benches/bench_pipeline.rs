//! Perf-1: coordinator overhead — full protocol runs through the DES
//! fast path vs the real threaded pipeline, at paper scale. The pipeline
//! should cost only the channel-hop overhead on top of the DES (<2× at
//! paper granularity), and both produce identical trajectories.
//!
//! Run: `cargo bench --bench bench_pipeline`

use edgepipe::bench::Bench;
use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::coordinator::pipeline::run_pipelined;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::RidgeModel;

fn main() {
    let mut bench = Bench::new();
    let raw = synth_calhousing(&SynthSpec::default());
    let (train, _) = train_split(&raw, 0.9, 42);
    let t = 1.5 * train.n as f64;

    for n_c in [100usize, 1378, 10000] {
        let cfg = DesConfig {
            record_blocks: false,
            ..DesConfig::paper(n_c, 100.0, t, 7)
        };
        let updates = {
            let mut exec = NativeExecutor::new(
                RidgeModel::new(train.d, cfg.lambda, train.n),
                cfg.alpha,
            );
            run_des(&train, &cfg, &mut IdealChannel, &mut exec)
                .unwrap()
                .updates
        };
        bench.run(
            &format!("DES full run (n_c={n_c}, {updates} updates)"),
            updates as f64,
            || {
                let mut exec = NativeExecutor::new(
                    RidgeModel::new(train.d, cfg.lambda, train.n),
                    cfg.alpha,
                );
                std::hint::black_box(
                    run_des(&train, &cfg, &mut IdealChannel, &mut exec)
                        .unwrap()
                        .final_loss,
                );
            },
        );
        bench.run(
            &format!("threaded pipeline (n_c={n_c}, {updates} updates)"),
            updates as f64,
            || {
                let mut exec = NativeExecutor::new(
                    RidgeModel::new(train.d, cfg.lambda, train.n),
                    cfg.alpha,
                );
                std::hint::black_box(
                    run_pipelined(
                        &train,
                        &cfg,
                        &mut IdealChannel,
                        &mut exec,
                    )
                    .unwrap()
                    .final_loss,
                );
            },
        );
    }
}
