//! Ablation: adaptive per-block payload schedules vs the paper's fixed
//! bound-optimal ñ_c — does warming the block size up (small early, big
//! late) beat a constant block size?
//!
//! Run: `cargo bench --bench bench_adaptive`

use edgepipe::bench::Bench;
use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::DesConfig;
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::extensions::adaptive::{
    run_scheduled, BlockSchedule, DeadlineAwareSchedule, FixedSchedule,
    WarmupSchedule,
};
use edgepipe::model::RidgeModel;

fn main() {
    let mut bench = Bench::new();
    let fast = std::env::var("EDGEPIPE_BENCH_FAST").is_ok();
    bench.run_once("adaptive schedules vs fixed ñ_c", || {
        let raw = synth_calhousing(&SynthSpec::default());
        let (train, _) = train_split(&raw, 0.9, 42);
        let t = 1.5 * train.n as f64;
        let seeds = if fast { 2 } else { 8 };
        println!(
            "{:>7} | {:<26} | {:>12} | {:>9}",
            "n_o", "schedule", "mean loss", "delivered"
        );
        for n_o in [10.0, 100.0, 1000.0] {
            // fixed at the bound optimum for this overhead (from fig3)
            let nc_opt = match n_o as usize {
                10 => 437,
                100 => 1378,
                _ => 5203,
            };
            let mk_scheds = || -> Vec<Box<dyn BlockSchedule>> {
                vec![
                    Box::new(FixedSchedule(nc_opt)),
                    Box::new(WarmupSchedule::new(16, 2.0, nc_opt)),
                    Box::new(WarmupSchedule::new(64, 4.0, 4 * nc_opt)),
                    Box::new(DeadlineAwareSchedule {
                        t_budget: t,
                        n_o,
                        aggressiveness: 0.08,
                    }),
                ]
            };
            let names: Vec<String> =
                mk_scheds().iter().map(|s| s.name()).collect();
            for (si, name) in names.iter().enumerate() {
                let mut total = 0.0;
                let mut delivered = 0usize;
                for s in 0..seeds {
                    let cfg = DesConfig {
                        record_blocks: false,
                        ..DesConfig::paper(nc_opt, n_o, t, 7 + s as u64)
                    };
                    let mut exec = NativeExecutor::new(
                        RidgeModel::new(train.d, cfg.lambda, train.n),
                        cfg.alpha,
                    );
                    let mut sched = mk_scheds().remove(si);
                    let run = run_scheduled(
                        &train,
                        &cfg,
                        sched.as_mut(),
                        &mut IdealChannel,
                        &mut exec,
                    )
                    .unwrap();
                    total += run.final_loss;
                    delivered = run.samples_delivered;
                }
                println!(
                    "{:>7} | {:<26} | {:>12.6} | {:>9}",
                    n_o,
                    name,
                    total / seeds as f64,
                    delivered
                );
            }
        }
        println!(
            "(warmup buys earlier first-update at the cost of extra \
             overhead packets; the gain concentrates at large n_o)"
        );
    });
}
