//! Bench: regenerate paper Fig. 4 (average training-loss curves; the
//! bound optimum ñ_c vs the experimental optimum n_c*, incl. the ≈3.8 %
//! penalty headline).
//!
//! Full paper scale by default; `EDGEPIPE_BENCH_FAST=1` shrinks the MC
//! sweep for CI. Run: `cargo bench --bench bench_fig4`

use edgepipe::bench::Bench;
use edgepipe::bound::corollary1::BoundParams;
use edgepipe::bound::estimate_constants;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::sweep::fig4::{fig4_data, Fig4Config};

fn main() {
    let mut bench = Bench::new();
    let fast = std::env::var("EDGEPIPE_BENCH_FAST").is_ok();

    bench.run_once("fig4: loss curves + nc* search (paper setup)", || {
        let raw = synth_calhousing(&SynthSpec::default());
        let (train, _) = train_split(&raw, 0.9, 42);
        let t = 1.5 * train.n as f64;
        let k = estimate_constants(&train, 0.05, 1e-4, 2000, 42);
        let params = BoundParams {
            alpha: 1e-4,
            big_l: k.big_l,
            c: k.c,
            m: 1.0,
            m_g: 1.0,
            d_diam: k.d_diam,
        };
        let cfg = Fig4Config {
            seeds: if fast { 3 } else { 10 },
            search_points: if fast { 8 } else { 24 },
            ..Fig4Config::paper(100.0, t)
        };
        let out = fig4_data(&train, &params, &cfg).expect("fig4 sweep");
        print!("{}", out.render());
        println!("search grid:");
        for (nc, s) in &out.search {
            println!("  n_c={:>6}  final {:.6} ± {:.6}", nc, s.mean, s.std);
        }
    });
}
