//! Perf-1 micro-benchmarks: the native hot path — single-sample SGD
//! update throughput, device block sampling, and full-dataset loss
//! evaluation. These are the numbers the §Perf optimization pass tracks.
//!
//! Run: `cargo bench --bench bench_engine`

use edgepipe::bench::Bench;
use edgepipe::coordinator::DeviceTransmitter;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::RidgeModel;
use edgepipe::sgd::{SgdEngine, StoreView};
use edgepipe::util::rng::Pcg32;

fn main() {
    let mut bench = Bench::new();
    let raw = synth_calhousing(&SynthSpec::default());
    let (train, _) = train_split(&raw, 0.9, 42);
    let store = StoreView::new(&train.x, &train.y, train.d);
    let model = RidgeModel::new(train.d, 0.05, train.n);
    let engine = SgdEngine::new(1e-4);

    // ---- SGD update throughput (the innermost loop of everything)
    const UPDATES: usize = 2_000_000;
    bench.run("native sgd updates (d=8, f64)", UPDATES as f64, || {
        let mut w = vec![0.1f64; train.d];
        let mut rng = Pcg32::seeded(1);
        engine.run_updates(&model, &mut w, store, UPDATES, &mut rng);
        std::hint::black_box(&w);
    });

    // ---- replayed-index variant (what the coordinator actually calls)
    let mut rng = Pcg32::seeded(2);
    let indices: Vec<u32> = (0..UPDATES)
        .map(|_| rng.gen_range(train.n as u64) as u32)
        .collect();
    bench.run("native sgd replay (pre-sampled idx)", UPDATES as f64, || {
        let mut w = vec![0.1f64; train.d];
        engine.run_indices(&model, &mut w, store, &indices);
        std::hint::black_box(&w);
    });

    // ---- full-dataset loss evaluation
    bench.run("full-dataset ridge loss (N=18576)", train.n as f64, || {
        let w = vec![0.1f64; train.d];
        std::hint::black_box(
            train.ridge_loss(&w, 0.05 / train.n as f64),
        );
    });

    // ---- device-side block sampling + gather
    bench.run("device sampling (full pass, n_c=437)", train.n as f64, || {
        let mut dev = DeviceTransmitter::new(&train, 437, 3);
        let mut total = 0usize;
        while let Some((_, _, y)) = dev.next_block() {
            total += y.len();
        }
        assert_eq!(total, train.n);
    });

    // ---- RNG
    bench.run("pcg32 next_u64 x10M", 10_000_000.0, || {
        let mut rng = Pcg32::seeded(9);
        let mut acc = 0u64;
        for _ in 0..10_000_000 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        std::hint::black_box(acc);
    });
}
