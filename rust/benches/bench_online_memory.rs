//! Bench Abl-4 (paper Sec. 6 future work): limited edge memory with
//! reservoir eviction. Final loss vs store capacity — how small can the
//! edge store be before the protocol degrades?
//!
//! Run: `cargo bench --bench bench_online_memory`

use edgepipe::bench::Bench;
use edgepipe::coordinator::des::DesConfig;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::extensions::online::capacity_sweep;

fn main() {
    let mut bench = Bench::new();
    let fast = std::env::var("EDGEPIPE_BENCH_FAST").is_ok();
    bench.run_once("online memory: loss vs edge store capacity", || {
        let raw = synth_calhousing(&SynthSpec::default());
        let (train, _) = train_split(&raw, 0.9, 42);
        let t = 1.5 * train.n as f64;
        let cfg = DesConfig {
            record_blocks: false,
            ..DesConfig::paper(1378, 100.0, t, 7)
        };
        let caps = vec![64, 256, 1024, 4096, train.n];
        let seeds = if fast { 2 } else { 6 };
        let rows = capacity_sweep(&train, &cfg, &caps, seeds);
        println!("{:>9} | {:>12}", "capacity", "mean loss");
        for (cap, loss) in &rows {
            println!("{:>9} | {:>12.6}", cap, loss);
        }
        let full = rows.last().unwrap().1;
        for (cap, loss) in &rows {
            if (loss - full) / full < 0.05 {
                println!(
                    "capacity {} already within 5% of unbounded memory",
                    cap
                );
                break;
            }
        }
    });
}
