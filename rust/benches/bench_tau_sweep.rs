//! Bench Abl-2: sensitivity to the compute rate τ_p — how the
//! bound-optimal block size ñ_c and the achieved loss move as the edge
//! processor gets slower relative to the channel.
//!
//! Run: `cargo bench --bench bench_tau_sweep`

use edgepipe::bench::Bench;
use edgepipe::bound::corollary1::BoundParams;
use edgepipe::bound::estimate_constants;
use edgepipe::bound::optimizer::optimize_block_size;
use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::RidgeModel;

fn main() {
    let mut bench = Bench::new();
    bench.run_once("tau_p sweep: ñ_c and loss vs compute rate", || {
        let raw = synth_calhousing(&SynthSpec::default());
        let (train, _) = train_split(&raw, 0.9, 42);
        let t = 1.5 * train.n as f64;
        let n_o = 100.0;
        let k = estimate_constants(&train, 0.05, 1e-4, 2000, 42);
        let params = BoundParams {
            alpha: 1e-4,
            big_l: k.big_l,
            c: k.c,
            m: 1.0,
            m_g: 1.0,
            d_diam: k.d_diam,
        };
        println!(
            "{:>6} | {:>7} {:>9} | {:>12} {:>10}",
            "tau_p", "ñ_c", "case", "final loss", "updates"
        );
        for tau_p in [0.25, 0.5, 1.0, 2.0, 4.0] {
            let opt = optimize_block_size(&params, train.n, t, n_o, tau_p);
            let cfg = DesConfig {
                tau_p,
                record_blocks: false,
                ..DesConfig::paper(opt.n_c, n_o, t, 7)
            };
            let mut exec = NativeExecutor::new(
                RidgeModel::new(train.d, cfg.lambda, train.n),
                cfg.alpha,
            );
            let run = run_des(&train, &cfg, &mut IdealChannel, &mut exec)
                .unwrap();
            println!(
                "{:>6} | {:>7} {:>9} | {:>12.6} {:>10}",
                tau_p,
                opt.n_c,
                format!("{:?}", opt.case),
                run.final_loss,
                run.updates
            );
        }
        println!(
            "(slower processor -> fewer updates fit -> the bias/variance \
             balance and ñ_c shift)"
        );
    });
}
