//! Bench Abl-3 (paper Sec. 6 future work): packet erasures with ARQ.
//! Final loss vs loss probability, and how the best block size shifts —
//! lost packets waste whole blocks, so smaller blocks hedge.
//!
//! Run: `cargo bench --bench bench_channel_error`

use edgepipe::bench::Bench;
use edgepipe::channel::ErasureChannel;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::RidgeModel;

fn main() {
    let mut bench = Bench::new();
    let fast = std::env::var("EDGEPIPE_BENCH_FAST").is_ok();
    bench.run_once("erasure channel: loss and best n_c vs p_loss", || {
        let raw = synth_calhousing(&SynthSpec::default());
        let (train, _) = train_split(&raw, 0.9, 42);
        let t = 1.5 * train.n as f64;
        let n_o = 100.0;
        let seeds = if fast { 2 } else { 5 };
        let grid: Vec<usize> = vec![200, 600, 1378, 4000, 10000];
        println!(
            "{:>7} | {:>8} {:>12} | per-n_c mean loss",
            "p_loss", "best n_c", "best loss"
        );
        for p_loss in [0.0, 0.1, 0.3, 0.5] {
            let mut rows = Vec::new();
            for &n_c in &grid {
                let mut total = 0.0;
                for s in 0..seeds {
                    let cfg = DesConfig {
                        record_blocks: false,
                        ..DesConfig::paper(n_c, n_o, t, 7 + s as u64)
                    };
                    let mut ch = ErasureChannel::new(p_loss);
                    let mut exec = NativeExecutor::new(
                        RidgeModel::new(train.d, cfg.lambda, train.n),
                        cfg.alpha,
                    );
                    total += run_des(&train, &cfg, &mut ch, &mut exec)
                        .unwrap()
                        .final_loss;
                }
                rows.push((n_c, total / seeds as f64));
            }
            let best = rows
                .iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            let detail: Vec<String> = rows
                .iter()
                .map(|(nc, l)| format!("{nc}:{l:.4}"))
                .collect();
            println!(
                "{:>7} | {:>8} {:>12.6} | {}",
                p_loss,
                best.0,
                best.1,
                detail.join("  ")
            );
        }
    });
}
