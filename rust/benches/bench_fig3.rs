//! Bench: regenerate paper Fig. 3 (Corollary-1 bound vs n_c per
//! overhead) and time the bound evaluation / optimizer primitives.
//!
//! Run: `cargo bench --bench bench_fig3`

use edgepipe::bench::Bench;
use edgepipe::bound::corollary1::{corollary1_bound, BoundParams};
use edgepipe::bound::estimate_constants;
use edgepipe::bound::optimizer::optimize_block_size;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::sweep::fig3::fig3_data;

fn main() {
    let mut bench = Bench::new();

    // ------- the figure itself (macro) -------
    bench.run_once("fig3: bound curves + markers (paper setup)", || {
        let raw = synth_calhousing(&SynthSpec::default());
        let (train, _) = train_split(&raw, 0.9, 42);
        let t = 1.5 * train.n as f64;
        let k = estimate_constants(&train, 0.05, 1e-4, 2000, 42);
        let params = BoundParams {
            alpha: 1e-4,
            big_l: k.big_l,
            c: k.c,
            m: 1.0,
            m_g: 1.0,
            d_diam: k.d_diam,
        };
        let out = fig3_data(
            &params,
            train.n,
            t,
            1.0,
            &[1.0, 10.0, 100.0, 1000.0],
            160,
        )
        .expect("fig3 grid");
        print!("{}", out.render());
    });

    // ------- robustness of ñ_c to constant-estimation error -------
    bench.run_once("fig3 sensitivity: regret under 2x constant errors", || {
        use edgepipe::bound::sensitivity::{max_regret, sensitivity_sweep};
        let truth = BoundParams::paper_fig3(6.4);
        let rows = sensitivity_sweep(
            &truth,
            18576,
            1.5 * 18576.0,
            100.0,
            1.0,
            &[0.5, 0.8, 1.25, 2.0],
        );
        println!(
            "{:>6} {:>7} | {:>7} | {:>10}",
            "const", "factor", "ñ_c", "regret"
        );
        for r in &rows {
            println!(
                "{:>6} {:>7} | {:>7} | {:>9.3}%",
                r.constant,
                r.factor,
                r.n_c,
                100.0 * r.regret
            );
        }
        println!("max regret: {:.3}%", 100.0 * max_regret(&rows));
    });

    // ------- primitives (micro) -------
    let params = BoundParams::paper_fig3(6.4);
    let (n, t) = (18576usize, 1.5 * 18576.0);
    bench.run("corollary1_bound eval x10k", 10_000.0, || {
        let mut acc = 0.0;
        for i in 0..10_000 {
            let nc = 1.0 + (i % 18575) as f64;
            acc += corollary1_bound(&params, n, t, nc, 100.0, 1.0, false);
        }
        std::hint::black_box(acc);
    });
    bench.run("optimize_block_size full scan (N=18576)", n as f64, || {
        std::hint::black_box(optimize_block_size(&params, n, t, 100.0, 1.0));
    });
}
