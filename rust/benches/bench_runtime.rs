//! Perf-1: PJRT artifact-call latencies — the `sgd_block` step (the hot
//! path of the PJRT backend), the masked full-dataset loss, and the MLP
//! step. Skips cleanly when artifacts are not built.
//!
//! Run: `cargo bench --bench bench_runtime`

use edgepipe::bench::Bench;
use edgepipe::coordinator::BlockExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::runtime::mlp::{MlpParams, PjrtMlp};
use edgepipe::runtime::{
    find_artifact_dir, PjrtExecutor, PjrtLossEvaluator, RuntimeSession,
};
use edgepipe::sgd::StoreView;
use edgepipe::util::rng::Pcg32;

fn main() {
    let Some(dir) = find_artifact_dir() else {
        println!("artifacts not built — skipping runtime benches");
        return;
    };
    let mut bench = Bench::new();
    let raw = synth_calhousing(&SynthSpec::default());
    let (train, _) = train_split(&raw, 0.9, 42);
    let store = StoreView::new(&train.x, &train.y, train.d);

    // ---- sgd_block step latency (full K_MAX=512 chunk)
    {
        let session = RuntimeSession::open(&dir).unwrap();
        let mut exec =
            PjrtExecutor::new(session, 1e-4, 0.05, train.n).unwrap();
        let mut rng = Pcg32::seeded(1);
        let indices: Vec<u32> = (0..512)
            .map(|_| rng.gen_range(train.n as u64) as u32)
            .collect();
        let mut w = vec![0.1f64; train.d];
        bench.run("pjrt sgd_block call (512 updates)", 512.0, || {
            exec.run_block(&mut w, store, &indices).unwrap();
        });
        // per-update amortized cost at protocol granularity
        let indices_small: Vec<u32> = indices[..64].to_vec();
        bench.run("pjrt sgd_block call (64 updates)", 64.0, || {
            exec.run_block(&mut w, store, &indices_small).unwrap();
        });
    }

    // ---- masked full-dataset loss
    {
        let session = RuntimeSession::open(&dir).unwrap();
        let mut eval = PjrtLossEvaluator::new(session, 0.05, train.n).unwrap();
        eval.append_rows(&train.x, &train.y).unwrap();
        let w = vec![0.1f64; train.d];
        bench.run("pjrt dataset_loss (N_CAP=21504)", train.n as f64, || {
            std::hint::black_box(eval.loss(&w).unwrap());
        });
    }

    // ---- MLP step (the MXU showcase path)
    {
        let session = RuntimeSession::open(&dir).unwrap();
        let mut mlp = PjrtMlp::new(session).unwrap();
        let mut rng = Pcg32::seeded(2);
        let mut params = MlpParams::init(mlp.d_in, mlp.hidden, &mut rng);
        let x: Vec<f32> = (0..mlp.batch * mlp.d_in)
            .map(|_| rng.next_gaussian() as f32)
            .collect();
        let y: Vec<f32> =
            (0..mlp.batch).map(|_| rng.next_gaussian() as f32).collect();
        let flops = 2.0 * mlp.batch as f64
            * (mlp.d_in * mlp.hidden
                + mlp.hidden * mlp.hidden
                + mlp.hidden) as f64
            * 3.0; // fwd + 2 bwd matmul passes, rough
        bench.run("pjrt mlp_step (batch 256, 68k params)", flops, || {
            std::hint::black_box(
                mlp.step(&mut params, &x, &y, 0.01).unwrap(),
            );
        });
    }
}
