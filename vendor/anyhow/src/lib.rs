//! A minimal, dependency-free, offline-safe subset of the `anyhow` API.
//!
//! The build image has no access to crates.io, so the crate vendors the
//! slice of `anyhow` it actually uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics match upstream for this subset:
//!
//! * `{}` displays the outermost message, `{:#}` joins the whole cause
//!   chain with `": "`, and `{:?}` renders a `Caused by:` listing;
//! * any `std::error::Error` converts into [`Error`] via `?`, capturing
//!   its source chain;
//! * `.context(..)` / `.with_context(..)` wrap both `Result` and
//!   `Option`.

use std::convert::Infallible;
use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// A message-chain error type. The first entry is the outermost context;
/// the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the chain, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("non-empty chain")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any standard error converts via `?`, capturing its source chain.
/// (`Error` itself deliberately does NOT implement `std::error::Error`,
/// exactly like upstream anyhow, so this blanket impl is coherent.)
impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::*;

    /// Private extension trait so `Context` covers both plain
    /// `std::error::Error` values and already-wrapped [`Error`]s
    /// (upstream anyhow's `ext::StdError` pattern).
    pub trait IntoError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E> IntoError for E
    where
        E: StdError + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl IntoError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Attach context to errors, on both `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::IntoError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|err| err.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|err| err.ext_context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(
                ::std::concat!(
                    "Condition failed: `",
                    ::std::stringify!($cond),
                    "`"
                ),
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let err: Error =
            Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{err}"), "reading config");
        assert_eq!(format!("{err:#}"), "reading config: missing file");
        assert!(format!("{err:?}").contains("Caused by:"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "not a number".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_build_messages() {
        fn f(x: usize) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Err(anyhow!("fell through at {}", x))
        }
        assert_eq!(format!("{}", f(50).unwrap_err()), "x too big: 50");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", f(1).unwrap_err()), "fell through at 1");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let err = none.context("nothing here").unwrap_err();
        assert_eq!(format!("{err}"), "nothing here");
        let some = Some(7u8).with_context(|| "unused").unwrap();
        assert_eq!(some, 7);
    }

    #[test]
    fn with_context_chains() {
        let err: Error = Err::<(), _>(io_err())
            .with_context(|| format!("step {}", 2))
            .unwrap_err();
        let chain: Vec<&str> = err.chain().collect();
        assert_eq!(chain, vec!["step 2", "missing file"]);
        assert_eq!(err.root_cause(), "missing file");
    }
}
